//! Analytic cost model: hardware profiles + workload -> task durations.
//!
//! Calibration anchors (all from the paper's Motivation section):
//! * llama-7B on the workstation: gradient offload `14 GB / ~15 GB/s ≈ 0.93 s`;
//!   fused CPU Adam over 7 B params `≈ 1.92 s`; GPU fwd+bwd `≈ 1.53-1.66 s`;
//!   one llama layer's fwd+bwd on the CPU `≈ 4.9 s`.
//! * GPT2-1.3B on the laptop (Table 5): 2.6 GB params, 10-15 GB/s PCIe,
//!   4 GB GPU memory.

/// Hardware profile of one commodity testbed.
#[derive(Debug, Clone)]
pub struct HardwareProfile {
    pub name: &'static str,
    /// Effective GPU throughput for fwd/bwd matmuls (FLOP/s, fp16/bf16).
    pub gpu_flops: f64,
    /// Effective CPU throughput for dense fwd/bwd (FLOP/s).
    pub cpu_flops: f64,
    /// Fused CPU Adam throughput (parameters / second).
    pub cpu_adam_params_per_s: f64,
    /// Speedup the multi-threaded fused Adam achieves over one worker —
    /// i.e. the factor *lost* when a span runs single-threaded.  The
    /// runtime's `optim::adam_span` drops to one thread below
    /// `optim::PAR_ADAM_MIN_LEN` elements, so chunked updates with
    /// sub-threshold chunks pay `cpu_adam_params_per_s / cpu_adam_parallelism`
    /// (see [`chunk_updater_penalty`]).
    pub cpu_adam_parallelism: f64,
    /// PCIe effective bandwidth per direction (bytes/s), pinned buffers.
    pub h2d_bytes_per_s: f64,
    pub d2h_bytes_per_s: f64,
    /// Effective bandwidth for bulk swap streaming (Fig. 3c-type systems).
    /// The paper's own arithmetic (40 GB -> 5.33 s) uses ~7.5 GB/s: large
    /// unpinned swap traffic achieves roughly half the pinned-buffer rate.
    pub swap_bytes_per_s: f64,
    /// GPU HBM/GDDR bandwidth (bytes/s) — bounds elementwise update steps.
    pub gpu_mem_bytes_per_s: f64,
    pub gpu_mem_bytes: u64,
    pub cpu_mem_bytes: u64,
}

impl HardwareProfile {
    /// RTX 4090 (24 GB) + Threadripper 3970X (252 GB) — paper Table 1.
    pub fn workstation() -> Self {
        HardwareProfile {
            name: "workstation-4090",
            // 4090 peak bf16 is ~165 TFLOP/s; the paper's measured fwd+bwd
            // (~1.6 s for llama-7B over 2048 tokens) implies ~55 TFLOP/s
            // achieved at these small batch sizes.
            gpu_flops: 55e12,
            cpu_flops: 0.5e12,
            // 7 B params in 1.92 s.
            cpu_adam_params_per_s: 7e9 / 1.92,
            // The 1.92 s figure is the fully-threaded fused kernel; a
            // single worker on the Threadripper runs ~4x slower (memory
            // bandwidth stops scaling past a few cores).
            cpu_adam_parallelism: 4.0,
            h2d_bytes_per_s: 15e9,
            d2h_bytes_per_s: 15e9,
            swap_bytes_per_s: 7.5e9,
            gpu_mem_bytes_per_s: 1000e9,
            gpu_mem_bytes: 24 << 30,
            cpu_mem_bytes: 252u64 << 30,
        }
    }

    /// A1000 laptop (4 GB) + i7-12800H (32 GB) — paper Table 5.
    pub fn laptop() -> Self {
        HardwareProfile {
            name: "laptop-a1000",
            gpu_flops: 4e12,
            cpu_flops: 0.15e12,
            cpu_adam_params_per_s: 1.2e9,
            cpu_adam_parallelism: 2.0,
            h2d_bytes_per_s: 12e9,
            d2h_bytes_per_s: 12e9,
            swap_bytes_per_s: 6e9,
            gpu_mem_bytes_per_s: 110e9,
            gpu_mem_bytes: 4u64 << 30,
            cpu_mem_bytes: 32u64 << 30,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "workstation" | "workstation-4090" | "4090" => Some(Self::workstation()),
            "laptop" | "laptop-a1000" | "a1000" => Some(Self::laptop()),
            _ => None,
        }
    }
}

/// Cap on per-layer chunk *tasks* in the DES builders (see
/// `Workload::layer_chunks`): the pipelining effect saturates by C = 64
/// while task counts would explode for paper-scale payloads under small
/// chunk budgets.
pub const MAX_DES_CHUNK_TASKS_PER_LAYER: u64 = 64;

/// CPU-updater slowdown factor for sub-layer chunked schedules: the
/// runtime's `optim::adam_span` runs single-threaded below
/// [`crate::optim::PAR_ADAM_MIN_LEN`] elements, so a chunk budget under
/// that threshold forfeits the fused kernel's thread-level speedup and
/// each chunk's update costs `parallelism`x its share of the whole-span
/// time.  `chunk_elems = 0` (chunking off) or a budget at/above the
/// threshold keeps the parallel rate (factor 1).  Keyed off the *same
/// constant* the runtime dispatch uses, so the sim cannot drift from the
/// kernel (pinned by `penalty_threshold_matches_runtime_dispatch`).
pub fn chunk_updater_penalty(chunk_elems: usize, parallelism: f64) -> f64 {
    if chunk_elems == 0 || chunk_elems >= crate::optim::PAR_ADAM_MIN_LEN {
        1.0
    } else {
        parallelism.max(1.0)
    }
}

/// One training workload: model scale + batch + LSP configuration.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub n_layers: usize,
    /// Total transformer parameters (excludes tied embeddings for comm).
    pub params: u64,
    /// Tokens processed per iteration (batch * seq).
    pub tokens: u64,
    pub bytes_per_param: u64,
    /// LSP subspace size per weight matrix (0 = full-parameter offload).
    pub d_sub: usize,
    /// Weight matrices per layer that LSP compresses (qkv, o, fc, proj).
    pub matrices_per_layer: usize,
    /// Non-zeros per projector row (compress cost is O(r * mn)).
    pub r: usize,
    /// bwd cost multiplier over fwd (2.0 plain, 3.0 with full recompute
    /// gradient checkpointing; the paper enables checkpointing).
    pub bwd_mult: f64,
    /// Wire codec for the offload/upload payloads (`--link-codec` in the
    /// simulator).  `None` = price transfers at `bytes_per_param` (the
    /// native precision, pre-codec behavior); `Some(kind)` prices them at
    /// the codec's analytic bytes/element for dense payloads.
    pub link_codec: Option<crate::codec::CodecKind>,
    /// `async-lsp` importance fraction rho: the top-rho slice updates
    /// on-GPU and never crosses a link; only the (1-rho) tail is priced as
    /// offload traffic (`--async-rho` in the simulator).
    pub async_rho: f64,
    /// `async-lsp` bounded-staleness window S: tail deltas may lag up to S
    /// iterations, so their link exposure amortizes over a window of S+1
    /// steps (`--async-staleness`).
    pub async_staleness: u64,
    /// Sub-layer chunking budget (`--link-chunk-elems` in the simulator,
    /// mirroring `TrainConfig::link_chunk_elems`): each link payload splits
    /// into `ceil(n / link_chunk_elems)` wire chunks so the offload ->
    /// CPU-update -> upload tail pipelines chunk-wise (PIPO-style).  `0` =
    /// whole-payload transfers, the pre-chunking schedule.
    pub link_chunk_elems: usize,
    /// Concurrent tenant pipelines sharing the links and the CPU updater
    /// (`--tenants` in the simulator, mirroring `TrainConfig::tenants`).
    /// 1 = the solo schedules; the `MultiTenant` DES kind lays out this
    /// many lsp-layerwise replicas over the shared resources.
    pub tenants: usize,
    /// Forward-only serving (`--schedule infer`): in-flight h2d layer
    /// weight streams — the modeled device weight budget in layers
    /// (`--prefetch-depth`, mirroring `InferConfig::prefetch_depth`).
    /// 1 = unpipelined (stream then compute, serially); >= 2 overlaps
    /// layer l's compute with layer l+1's stream.
    pub prefetch_depth: usize,
}

impl Workload {
    pub fn paper(model: crate::model::memory::PaperModel, tokens: u64, d_sub: usize) -> Self {
        Workload {
            name: model.name().to_string(),
            n_layers: model.n_layers() as usize,
            params: model.params(),
            tokens,
            bytes_per_param: 2,
            d_sub,
            matrices_per_layer: 4,
            r: 8,
            bwd_mult: 2.0,
            link_codec: None,
            async_rho: 0.5,
            async_staleness: 2,
            link_chunk_elems: 0,
            tenants: 1,
            prefetch_depth: 2,
        }
    }

    /// Build from an artifact manifest (for simulating our real runs).
    pub fn from_manifest(man: &crate::model::Manifest, d_sub: usize) -> Self {
        let cfg = &man.config;
        Workload {
            name: format!("preset-{}", man.preset),
            n_layers: cfg.n_layer,
            params: cfg.n_params as u64,
            tokens: (cfg.batch * cfg.seq) as u64,
            bytes_per_param: 4, // f32 artifacts
            d_sub,
            matrices_per_layer: man.kinds.len().max(1),
            r: cfg.r,
            bwd_mult: 2.0,
            link_codec: None,
            async_rho: 0.5,
            async_staleness: 2,
            link_chunk_elems: 0,
            tenants: 1,
            prefetch_depth: 2,
        }
    }

    pub fn params_per_layer(&self) -> u64 {
        self.params / self.n_layers as u64
    }

    pub fn layer_bytes(&self) -> u64 {
        self.params_per_layer() * self.bytes_per_param
    }

    /// Subspace elements per layer under LSP (d^2 per compressed matrix).
    pub fn sub_elems_per_layer(&self) -> u64 {
        (self.d_sub as u64).pow(2) * self.matrices_per_layer as u64
    }

    /// Wire bytes per payload element under the configured link codec
    /// (gradient payloads are dense, so density 1.0).
    pub fn wire_bytes_per_elem(&self) -> f64 {
        match self.link_codec {
            Some(kind) => kind.est_bytes_per_elem(1.0),
            None => self.bytes_per_param as f64,
        }
    }

    /// Encoded bytes of one layer's full-gradient payload.
    pub fn wire_layer_bytes(&self) -> f64 {
        self.params_per_layer() as f64 * self.wire_bytes_per_elem()
    }

    /// Encoded bytes of one layer's subspace payloads.
    pub fn wire_sub_bytes(&self) -> f64 {
        self.sub_elems_per_layer() as f64 * self.wire_bytes_per_elem()
    }

    /// Wire chunks per subspace payload (one d x d matrix gradient) under
    /// `link_chunk_elems` — the same counting rule the runtime split uses
    /// (`comm::n_chunks_for`).
    pub fn sub_payload_chunks(&self) -> u64 {
        crate::coordinator::comm::n_chunks_for(self.d_sub * self.d_sub, self.link_chunk_elems)
            as u64
    }

    /// Wire chunks per full-layer gradient payload under
    /// `link_chunk_elems`.
    pub fn full_layer_chunks(&self) -> u64 {
        crate::coordinator::comm::n_chunks_for(
            self.params_per_layer() as usize,
            self.link_chunk_elems,
        ) as u64
    }

    /// Chunk tasks one *layer's* transfer splits into in the DES builders:
    /// 1 when chunking is off; otherwise per-payload chunks summed over the
    /// layer's payloads (each compressed matrix chunks independently on the
    /// subspace path), CAPPED at [`MAX_DES_CHUNK_TASKS_PER_LAYER`].  The
    /// cap is a modeling resolution, not a silent behavior change: the
    /// chunk-pipelining effect saturates quickly (the `(C+1)/(2C)` factor
    /// is within 1% of its limit by C = 64) while the DES task count —
    /// and its runtime — would grow into the millions for paper-scale
    /// payloads under a 4096-element budget.  The closed forms
    /// ([`eq_chunked_iter`], [`chunked_gated_link_exposure`]) use the
    /// uncapped chunk counts.
    pub fn layer_chunks(&self, compressed: bool) -> u64 {
        let raw = if self.link_chunk_elems == 0 {
            1
        } else if compressed {
            // A layer task aggregates `matrices_per_layer` payloads; when
            // each payload stays whole (one chunk) the aggregate is the
            // unchunked layer task — returning `matrices` here would
            // change the DES at the n_chunks = 1 degeneracy point.
            match self.sub_payload_chunks() {
                0 | 1 => 1,
                per_payload => self.matrices_per_layer as u64 * per_payload,
            }
        } else {
            self.full_layer_chunks()
        };
        raw.min(MAX_DES_CHUNK_TASKS_PER_LAYER)
    }
}

/// All task durations (seconds) the schedules need.
#[derive(Debug, Clone)]
pub struct Costs {
    pub fwd_layer_gpu: f64,
    pub bwd_layer_gpu: f64,
    pub upd_layer_cpu_full: f64,
    pub upd_layer_cpu_sub: f64,
    pub offload_layer_full: f64,
    pub upload_layer_full: f64,
    pub offload_layer_sub: f64,
    pub upload_layer_sub: f64,
    /// GPU-side compress/decompress per layer (dense multiplies over the
    /// sparse-stored projectors — cheap relative to fwd/bwd).
    pub compress_layer_gpu: f64,
    pub apply_layer_gpu: f64,
    /// GPU-side full-parameter apply (Zero's `W += eta dW`), bandwidth-bound.
    pub apply_layer_full_gpu: f64,
    /// Full on-GPU fused Adam per layer (native baseline), bandwidth-bound.
    pub upd_layer_gpu_native: f64,
    pub fwd_layer_cpu: f64,
    pub bwd_layer_cpu: f64,
    /// [`chunk_updater_penalty`] for this workload's `link_chunk_elems`:
    /// multiplies CPU-update durations wherever a schedule actually splits
    /// the updater into sub-layer chunks (`cch > 1`); 1.0 when chunking is
    /// off or chunks stay at/above the parallel-dispatch threshold.
    pub upd_chunk_penalty: f64,
}

impl Costs {
    pub fn derive(hw: &HardwareProfile, w: &Workload) -> Costs {
        let p_layer = w.params_per_layer() as f64;
        // fwd FLOPs per layer ~ 2 * params * tokens.
        let fwd_flops = 2.0 * p_layer * w.tokens as f64;
        let fwd_layer_gpu = fwd_flops / hw.gpu_flops;
        let bwd_layer_gpu = w.bwd_mult * fwd_layer_gpu;
        // Link transfers are priced at the *encoded* payload size (the
        // workload's link codec); compute stays at native precision.
        let layer_bytes = w.wire_layer_bytes();
        let sub_elems = w.sub_elems_per_layer() as f64;
        let sub_bytes = w.wire_sub_bytes();
        // Compress cost on GPU with the sparse kernel (L1): stage 1 touches
        // every G element r times (2 r m n FLOPs), stage 2 is 2 r n d.
        // Dims per matrix: mn = p_layer / matrices, n ~ sqrt(mn).
        let mn = p_layer / w.matrices_per_layer as f64;
        let n_dim = mn.sqrt();
        let compress_flops = w.matrices_per_layer as f64
            * (2.0 * w.r as f64 * mn + 2.0 * w.r as f64 * n_dim * w.d_sub as f64);
        Costs {
            fwd_layer_gpu,
            bwd_layer_gpu,
            upd_layer_cpu_full: p_layer / hw.cpu_adam_params_per_s,
            upd_layer_cpu_sub: sub_elems / hw.cpu_adam_params_per_s,
            offload_layer_full: layer_bytes / hw.d2h_bytes_per_s,
            upload_layer_full: layer_bytes / hw.h2d_bytes_per_s,
            offload_layer_sub: sub_bytes / hw.d2h_bytes_per_s,
            upload_layer_sub: sub_bytes / hw.h2d_bytes_per_s,
            compress_layer_gpu: compress_flops / hw.gpu_flops,
            apply_layer_gpu: compress_flops / hw.gpu_flops,
            // W += eta*dW reads W+dW, writes W: ~3 elements of traffic.
            apply_layer_full_gpu: p_layer * 3.0 * w.bytes_per_param as f64
                / hw.gpu_mem_bytes_per_s,
            // Fused Adam touches w/g/m/v read+write: ~16 bytes per param fp16.
            upd_layer_gpu_native: p_layer * 8.0 * w.bytes_per_param as f64
                / hw.gpu_mem_bytes_per_s,
            fwd_layer_cpu: fwd_flops / hw.cpu_flops,
            bwd_layer_cpu: w.bwd_mult * fwd_flops / hw.cpu_flops,
            upd_chunk_penalty: chunk_updater_penalty(
                w.link_chunk_elems,
                hw.cpu_adam_parallelism,
            ),
        }
    }

    pub fn gpu_compute(&self, n_layers: usize) -> f64 {
        (self.fwd_layer_gpu + self.bwd_layer_gpu) * n_layers as f64
    }
}

/// Closed-form Eq. 1 (Zero's critical path).
pub fn eq1_zero_iter(c: &Costs, n: usize) -> f64 {
    let nf = n as f64;
    nf * c.fwd_layer_gpu
        + (nf * c.bwd_layer_gpu).max(nf * c.offload_layer_full)
        + (nf * c.upd_layer_cpu_full).max(nf * c.upload_layer_full)
}

/// Closed-form Eq. 4 (LSP's layer-wise critical path).
pub fn eq4_lsp_iter(c: &Costs, n: usize) -> f64 {
    let nf = n as f64;
    let comm_layer = c.offload_layer_sub + c.upload_layer_sub;
    let gpu_path = nf * (c.fwd_layer_gpu + c.bwd_layer_gpu + c.compress_layer_gpu + c.apply_layer_gpu)
        + comm_layer
        + c.upd_layer_cpu_sub;
    gpu_path
        .max(nf * c.offload_layer_sub)
        .max(nf * c.upload_layer_sub)
        .max(nf * c.upd_layer_cpu_sub)
}

/// Closed-form `async-lsp` (ZenFlow-style stall-free) iteration estimate:
/// the top-rho important slice updates on-GPU and never crosses a link;
/// the (1-rho) tail offloads with its CPU Adam delta applied within a
/// bounded staleness window S, so its pipeline-tail exposure amortizes over
/// S+1 iterations.  `rho = 0, S = 0` degenerates to Eq. 4's fully-gated
/// layer-wise path; `rho = 1` leaves only the GPU path.  The steady-state
/// resource bounds (either link, the CPU updater) shrink by the tail
/// fraction but do NOT amortize — a window delays work, it does not remove
/// it.
pub fn eq_async_lsp_iter(c: &Costs, n: usize, rho: f64, staleness: u64) -> f64 {
    let nf = n as f64;
    let q = 1.0 - rho.clamp(0.0, 1.0);
    let comm_layer = q * (c.offload_layer_sub + c.upload_layer_sub);
    let upd = q * c.upd_layer_cpu_sub;
    let gpu_path =
        nf * (c.fwd_layer_gpu + c.bwd_layer_gpu + c.compress_layer_gpu + c.apply_layer_gpu);
    let exposed = (comm_layer + upd) / (staleness as f64 + 1.0);
    (gpu_path + exposed)
        .max(nf * q * c.offload_layer_sub)
        .max(nf * q * c.upload_layer_sub)
        .max(nf * q * c.upd_layer_cpu_sub)
}

/// Predicted per-iteration **gated link exposure** — the quantity the
/// runtime's virtual-clock stall counter (`TrainReport::stall_secs` via
/// `PipelineCtx::note_gated_delta`) reports: every delta that gates the
/// schedule charges its round-trip link time, amortized over the staleness
/// window it was allowed to lag.  LSP gates every subspace delta at its
/// layer event (window 0, full charge); `async-lsp` gates only the
/// (1-rho) tail, each delta amortized by 1/(S+1).
pub fn gated_link_exposure(c: &Costs, n: usize, rho: f64, staleness: u64) -> f64 {
    let nf = n as f64;
    let q = 1.0 - rho.clamp(0.0, 1.0);
    nf * q * (c.offload_layer_sub + c.upload_layer_sub) / (staleness as f64 + 1.0)
}

/// LSP's gated link exposure (every delta fully charged): the rho = 0,
/// S = 0 corner of [`gated_link_exposure`].
pub fn lsp_gated_link_exposure(c: &Costs, n: usize) -> f64 {
    gated_link_exposure(c, n, 0.0, 0)
}

/// Makespan of one layer's offload -> CPU-update -> upload tail when it is
/// split into `n_chunks` sub-layer chunks (PIPO-style): the three stages
/// run on three different resources, so chunk i's upload overlaps chunk
/// i+1's update and chunk i+2's offload — the latency collapses from the
/// serial sum toward the slowest single stage:
///
/// ```text
/// tail(C) = (off + upd + up) / C  +  (C - 1) / C * max(off, upd, up)
/// ```
///
/// `C = 1` is exactly the serial sum (the unchunked behavior).
pub fn chunked_tail(offload: f64, upd: f64, upload: f64, n_chunks: u64) -> f64 {
    let c = n_chunks.max(1) as f64;
    (offload + upd + upload) / c + (c - 1.0) / c * offload.max(upd).max(upload)
}

/// Closed-form chunked schedule estimate: [`eq_async_lsp_iter`]'s critical
/// path with the per-layer pipeline tail shortened by sub-layer chunking
/// ([`chunked_tail`]).  The steady-state link bounds are untouched —
/// chunking *overlaps* transfers, it does not remove any — but the CPU
/// updater is priced with [`Costs::upd_chunk_penalty`]: sub-threshold
/// chunks drop the fused Adam to a single thread
/// (`optim::PAR_ADAM_MIN_LEN`), inflating both the per-layer tail and the
/// steady-state updater bound.  Degenerates EXACTLY to the unchunked
/// forms: `n_chunks = 1` returns `eq_async_lsp_iter(c, n, rho, staleness)`
/// verbatim (and therefore Eq. 4 at `rho = 0, S = 0`).
pub fn eq_chunked_iter(c: &Costs, n: usize, rho: f64, staleness: u64, n_chunks: u64) -> f64 {
    if n_chunks <= 1 {
        return eq_async_lsp_iter(c, n, rho, staleness);
    }
    let nf = n as f64;
    let q = 1.0 - rho.clamp(0.0, 1.0);
    let upd = q * c.upd_layer_cpu_sub * c.upd_chunk_penalty;
    let tail = chunked_tail(q * c.offload_layer_sub, upd, q * c.upload_layer_sub, n_chunks);
    let gpu_path =
        nf * (c.fwd_layer_gpu + c.bwd_layer_gpu + c.compress_layer_gpu + c.apply_layer_gpu);
    let exposed = tail / (staleness as f64 + 1.0);
    (gpu_path + exposed)
        .max(nf * q * c.offload_layer_sub)
        .max(nf * q * c.upload_layer_sub)
        .max(nf * upd)
}

/// Chunked gated link exposure — EXACTLY the formula the runtime's
/// virtual-clock stall counter applies per gating delta
/// (`PipelineCtx::note_gated_delta`): the unchunked exposure scaled by the
/// shared chunk-pipelining factor `(C + 1) / (2 C)`
/// (`comm::chunk_pipeline_factor` — both callers use the same function, so
/// the sim-vs-runtime stall agreement survives chunking).
pub fn chunked_gated_link_exposure(
    c: &Costs,
    n: usize,
    rho: f64,
    staleness: u64,
    n_chunks: u64,
) -> f64 {
    gated_link_exposure(c, n, rho, staleness)
        * crate::coordinator::comm::chunk_pipeline_factor(n_chunks)
}

/// Closed-form **aggregate** gated link exposure of `tenants` identical
/// lsp-layerwise pipelines sharing the arbiter's links — the quantity a
/// multi-tenant run's summed per-tenant virtual `stall_secs`
/// ([`crate::coordinator::report::MultiTenantReport::aggregate_stall_secs`])
/// reports per iteration.
///
/// Why a plain `K x` is the right model and not a contention term: the
/// virtual clock charges each chunk pure `wire_bytes / bandwidth`
/// arithmetic, deliberately independent of queueing (that is what makes
/// tenant trajectories bit-identical to solo runs), so each tenant's
/// modeled stall equals its solo exposure and the aggregate is exactly
/// `tenants` times the solo closed form ([`chunked_gated_link_exposure`]).
/// `tenants = 1` is bit-for-bit the solo form.
pub fn multi_tenant_gated_link_exposure(
    c: &Costs,
    n: usize,
    rho: f64,
    staleness: u64,
    n_chunks: u64,
    tenants: usize,
) -> f64 {
    tenants.max(1) as f64 * chunked_gated_link_exposure(c, n, rho, staleness, n_chunks)
}

/// Expected link-time inflation from planned retransmits: each planned
/// drop/corrupt costs one extra wire crossing per firing (up to the retry
/// budget), so a schedule moving `base_transfers` chunks prices its links at
/// `base * factor`.  This is the sim-side mirror of the runtime's
/// `retrans_bytes` accounting (`FaultPlan::planned_extra_transfers` counts
/// the same firings the link threads charge), so
/// `simulate --fault-plan` prices what `train --fault-plan` then measures.
pub fn expected_retransmit_factor(planned_extra: u64, base_transfers: u64) -> f64 {
    if base_transfers == 0 {
        1.0
    } else {
        1.0 + planned_extra as f64 / base_transfers as f64
    }
}

/// Closed-form forward-only serving iteration (`--schedule infer`): one
/// decode step streams every layer's weights h2d (`upload_layer_full`)
/// and runs its forward (`fwd_layer_gpu`).  At `prefetch_depth = 1` the
/// two serialize per layer:
///
/// ```text
/// T_infer(1) = n * (s + f)        s = upload_layer_full, f = fwd_layer_gpu
/// ```
///
/// At `prefetch_depth >= 2` layer l+1's stream overlaps layer l's compute
/// and the steady state is gated by the slower resource alone:
///
/// ```text
/// T_infer(d >= 2) = n * max(s, f)
/// ```
///
/// Depth beyond 2 buys nothing in steady state — with two slots the
/// stream resource never waits on a slot free (`compute_done[g-d]` lags
/// `stream_done[g-1]` for all `d >= 2` in the engine's recurrence) — so
/// the closed form is a function of `d = 1` vs `d >= 2` only.  The DES
/// builder ([`crate::sim::schedules`] `ScheduleKind::Infer`) models the
/// transient (first `d` layers have no overlap partner) that this form
/// ignores; the runtime agreement test bounds both against the engine's
/// measured recurrence.
pub fn eq_infer_iter(c: &Costs, n: usize, prefetch_depth: usize) -> f64 {
    let nf = n as f64;
    let s = c.upload_layer_full;
    let f = c.fwd_layer_gpu;
    if prefetch_depth <= 1 {
        nf * (s + f)
    } else {
        nf * s.max(f)
    }
}

/// Serving throughput prediction: tokens per second at the closed-form
/// iteration time ([`eq_infer_iter`]) — one decode step emits
/// `w.tokens` tokens (the batch).
pub fn infer_tokens_per_s(c: &Costs, w: &Workload, prefetch_depth: usize) -> f64 {
    let t = eq_infer_iter(c, w.n_layers, prefetch_depth);
    if t > 0.0 {
        w.tokens as f64 / t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::PaperModel;

    fn llama_ws() -> (HardwareProfile, Workload, Costs) {
        let hw = HardwareProfile::workstation();
        let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        let c = Costs::derive(&hw, &w);
        (hw, w, c)
    }

    #[test]
    fn infer_closed_form_depth_structure() {
        let (_, w, c) = llama_ws();
        let n = w.n_layers;
        let s = c.upload_layer_full;
        let f = c.fwd_layer_gpu;
        let d1 = eq_infer_iter(&c, n, 1);
        let d2 = eq_infer_iter(&c, n, 2);
        assert!((d1 - n as f64 * (s + f)).abs() < 1e-12, "depth 1 is the serial sum");
        assert!((d2 - n as f64 * s.max(f)).abs() < 1e-12, "depth 2 is the slower resource");
        // Steady state saturates at depth 2: more slots buy nothing.
        assert_eq!(d2.to_bits(), eq_infer_iter(&c, n, 4).to_bits());
        assert!(d2 < d1, "overlap must win");
        let tps1 = infer_tokens_per_s(&c, &w, 1);
        let tps2 = infer_tokens_per_s(&c, &w, 2);
        assert!(tps1 > 0.0 && tps2 > tps1, "throughput improves with prefetch");
    }

    #[test]
    fn calibration_matches_paper_narrative() {
        let (_, w, c) = llama_ws();
        // Gradient offload of 14 GB at 15 GB/s ~ 0.93 s.
        let offload_total = c.offload_layer_full * w.n_layers as f64;
        assert!((offload_total - 0.93).abs() < 0.05, "offload {offload_total}");
        // Fused CPU Adam over 7 B params ~ 1.92 s.
        let upd_total = c.upd_layer_cpu_full * w.n_layers as f64;
        assert!((upd_total - 1.92).abs() < 0.05, "upd {upd_total}");
        // GPU fwd+bwd ~ 1.5-1.8 s.
        let gpu = c.gpu_compute(w.n_layers);
        assert!((1.2..2.2).contains(&gpu), "gpu compute {gpu}");
        // One layer's fwd+bwd on CPU ~ 4.9 s (paper: "directly adds 4.9 s").
        let cpu_layer = c.fwd_layer_cpu + c.bwd_layer_cpu;
        assert!((3.5..6.5).contains(&cpu_layer), "cpu layer {cpu_layer}");
    }

    #[test]
    fn eq1_slowdown_in_paper_range() {
        // Paper: Zero's schedule slows training ~2.1-2.2x on the workstation.
        let (_, w, c) = llama_ws();
        let slow = eq1_zero_iter(&c, w.n_layers) / c.gpu_compute(w.n_layers);
        assert!((1.8..2.6).contains(&slow), "zero slowdown {slow}");
    }

    #[test]
    fn eq4_beats_eq1_substantially() {
        let (_, w, c) = llama_ws();
        let zero = eq1_zero_iter(&c, w.n_layers);
        let lsp = eq4_lsp_iter(&c, w.n_layers);
        assert!(lsp < zero * 0.7, "lsp {lsp} vs zero {zero}");
        // And LSP is within ~25% of pure GPU compute (near-native claim).
        let gpu = c.gpu_compute(w.n_layers);
        assert!(lsp < gpu * 1.35, "lsp {lsp} vs native {gpu}");
    }

    #[test]
    fn subspace_shrinks_comm_quadratically() {
        let hw = HardwareProfile::workstation();
        let w1 = Workload::paper(PaperModel::Llama7B, 2048, 1024);
        let w2 = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        let c1 = Costs::derive(&hw, &w1);
        let c2 = Costs::derive(&hw, &w2);
        let ratio = c2.offload_layer_sub / c1.offload_layer_sub;
        assert!((ratio - 4.0).abs() < 1e-6, "d^2 scaling, got {ratio}");
    }

    #[test]
    fn profiles_resolve_by_name() {
        assert!(HardwareProfile::by_name("workstation").is_some());
        assert!(HardwareProfile::by_name("laptop").is_some());
        assert!(HardwareProfile::by_name("tpu-pod").is_none());
    }

    #[test]
    fn async_estimate_degenerates_to_eq4_and_improves_monotonically() {
        let (_, w, c) = llama_ws();
        let n = w.n_layers;
        // rho = 0, S = 0 is exactly Eq. 4 (modulo f64 association).
        let eq4 = eq4_lsp_iter(&c, n);
        let async0 = eq_async_lsp_iter(&c, n, 0.0, 0);
        assert!((async0 - eq4).abs() / eq4 < 1e-12, "{async0} vs {eq4}");
        // More importance or more staleness never makes the estimate worse.
        let mut prev = async0;
        for s in 0..4u64 {
            let t = eq_async_lsp_iter(&c, n, 0.0, s);
            assert!(t <= prev + 1e-12, "staleness {s}: {t} > {prev}");
            prev = t;
        }
        let mut prev = eq_async_lsp_iter(&c, n, 0.0, 2);
        for rho in [0.25, 0.5, 0.75, 1.0] {
            let t = eq_async_lsp_iter(&c, n, rho, 2);
            assert!(t <= prev + 1e-12, "rho {rho}: {t} > {prev}");
            prev = t;
        }
        // rho = 1: pure GPU path, below LSP.
        assert!(eq_async_lsp_iter(&c, n, 1.0, 0) < eq4);
    }

    #[test]
    fn gated_exposure_predicts_the_stall_reduction() {
        let (_, w, c) = llama_ws();
        let n = w.n_layers;
        let lsp = lsp_gated_link_exposure(&c, n);
        assert!(lsp > 0.0);
        // The acceptance-criterion configuration (rho 0.5, S 2): the tail
        // halves the gated traffic and the window amortizes it 3x — an
        // 83% predicted stall reduction, comfortably past the >= 30% bar.
        let asynced = gated_link_exposure(&c, n, 0.5, 2);
        assert!((asynced / lsp - 0.5 / 3.0).abs() < 1e-12);
        assert!(asynced <= 0.7 * lsp, "predicted reduction must clear 30%");
        // Sole-window and sole-importance reductions match the arithmetic.
        assert!((gated_link_exposure(&c, n, 0.0, 2) / lsp - 1.0 / 3.0).abs() < 1e-12);
        assert!((gated_link_exposure(&c, n, 0.5, 0) / lsp - 0.5).abs() < 1e-12);
        assert_eq!(gated_link_exposure(&c, n, 1.0, 0), 0.0);
    }

    #[test]
    fn chunked_forms_degenerate_and_improve_monotonically() {
        let (_, w, c) = llama_ws();
        let n = w.n_layers;
        // n_chunks = 1 IS the unchunked form, bit for bit.
        for (rho, s) in [(0.0, 0u64), (0.5, 2), (1.0, 0)] {
            let un = eq_async_lsp_iter(&c, n, rho, s);
            let ch = eq_chunked_iter(&c, n, rho, s, 1);
            assert_eq!(ch.to_bits(), un.to_bits(), "rho {rho} S {s}");
        }
        assert_eq!(
            chunked_gated_link_exposure(&c, n, 0.0, 0, 1).to_bits(),
            lsp_gated_link_exposure(&c, n).to_bits()
        );
        // chunked_tail: serial sum at C = 1, slowest stage as C -> inf,
        // monotone non-increasing in between.
        let (a, u, b) = (3.0, 2.0, 1.0);
        assert_eq!(chunked_tail(a, u, b, 1), a + u + b);
        let mut prev = f64::INFINITY;
        for ch in 1..=64u64 {
            let t = chunked_tail(a, u, b, ch);
            assert!(t <= prev + 1e-12, "C {ch}: {t} > {prev}");
            assert!(t >= a, "never below the slowest stage");
            prev = t;
        }
        assert!((chunked_tail(a, u, b, 1 << 20) - a) < 1e-4);
        // The full estimate never gets worse with more chunks either.
        let mut prev = eq_chunked_iter(&c, n, 0.0, 0, 1);
        for ch in [2u64, 4, 16, 256] {
            let t = eq_chunked_iter(&c, n, 0.0, 0, ch);
            assert!(t <= prev + 1e-12, "C {ch}");
            prev = t;
        }
    }

    #[test]
    fn chunked_exposure_predicts_the_acceptance_margin() {
        // The acceptance shape: lsp at --link-chunk-elems 4096 on a paper
        // workload.  d = 2048 => 4 Mi elements per subspace payload =>
        // 1024 chunks => the pipelining factor is within a hair of 1/2,
        // comfortably past the >= 20% stall-reduction bar.
        let (_, mut w, c) = llama_ws();
        w.link_chunk_elems = 4096;
        let chunks = w.sub_payload_chunks();
        assert_eq!(chunks, 1024);
        let whole = lsp_gated_link_exposure(&c, w.n_layers);
        let chunked = chunked_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks);
        assert!(whole > 0.0);
        let reduction = 1.0 - chunked / whole;
        assert!(reduction >= 0.2, "predicted stall reduction {reduction} below 20%");
        // And the factor matches the runtime formula exactly.
        let factor = crate::coordinator::comm::chunk_pipeline_factor(chunks);
        assert!((chunked / whole - factor).abs() < 1e-12);
        // Chunk counting follows the runtime rule; the DES task-splitting
        // view additionally caps at MAX_DES_CHUNK_TASKS_PER_LAYER (the
        // pipelining factor is saturated well before 4 * 1024 chunks).
        assert_eq!(w.layer_chunks(true), MAX_DES_CHUNK_TASKS_PER_LAYER);
        w.link_chunk_elems = 1 << 22; // one 4 Mi-elem chunk per payload
        assert_eq!(w.sub_payload_chunks(), 1);
        // No payload splits => the layer task must stay the unchunked one
        // (the DES-side n_chunks = 1 degeneracy).
        assert_eq!(w.layer_chunks(true), 1, "whole payloads keep the unchunked layer task");
        w.link_chunk_elems = 0;
        assert_eq!(w.layer_chunks(true), 1);
        assert_eq!(w.sub_payload_chunks(), 1);
    }

    #[test]
    fn penalty_threshold_matches_runtime_dispatch() {
        // Sim-vs-runtime agreement: the cost model's single-thread cliff
        // must sit exactly where `optim::adam_span` drops to one worker.
        let t = crate::optim::PAR_ADAM_MIN_LEN;
        assert_eq!(chunk_updater_penalty(t, 4.0), 1.0, "at-threshold chunks stay parallel");
        assert_eq!(chunk_updater_penalty(t - 1, 4.0), 4.0, "below threshold pays full factor");
        assert_eq!(chunk_updater_penalty(0, 4.0), 1.0, "chunking off is penalty-free");
        assert_eq!(chunk_updater_penalty(4096, 0.5), 1.0, "parallelism < 1 clamps to 1");
        // Both shipped profiles model a real (> 1x) threaded speedup.
        assert!(HardwareProfile::workstation().cpu_adam_parallelism > 1.0);
        assert!(HardwareProfile::laptop().cpu_adam_parallelism > 1.0);
    }

    #[test]
    fn sub_threshold_chunks_inflate_the_updater_estimate() {
        let hw = HardwareProfile::workstation();
        let mut w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        w.link_chunk_elems = crate::optim::PAR_ADAM_MIN_LEN; // at threshold
        let c_ok = Costs::derive(&hw, &w);
        assert_eq!(c_ok.upd_chunk_penalty, 1.0);
        w.link_chunk_elems = 4096; // well below threshold
        let c_pen = Costs::derive(&hw, &w);
        assert_eq!(c_pen.upd_chunk_penalty, hw.cpu_adam_parallelism);
        // Same chunk count, different budget regime: the penalized
        // estimate is never better, and strictly worse once the
        // single-threaded updater dominates a stage.
        let n = w.n_layers;
        let ok = eq_chunked_iter(&c_ok, n, 0.0, 0, 64);
        let pen = eq_chunked_iter(&c_pen, n, 0.0, 0, 64);
        assert!(pen > ok, "penalized {pen} vs parallel {ok}");
        // The n_chunks = 1 degeneracy is untouched by the penalty field.
        assert_eq!(
            eq_chunked_iter(&c_pen, n, 0.0, 0, 1).to_bits(),
            eq_async_lsp_iter(&c_pen, n, 0.0, 0).to_bits()
        );
    }

    #[test]
    fn multi_tenant_exposure_is_k_times_solo_and_degenerates_at_one() {
        let (_, mut w, c) = llama_ws();
        w.link_chunk_elems = 4096;
        let chunks = w.sub_payload_chunks();
        let solo = chunked_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks);
        // tenants = 1 is the solo closed form, bit for bit.
        assert_eq!(
            multi_tenant_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks, 1).to_bits(),
            solo.to_bits()
        );
        // Virtual-clock charges are contention-independent, so K tenants
        // aggregate to exactly K x solo (and 0 clamps to 1 tenant).
        let k4 = multi_tenant_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks, 4);
        assert!((k4 / solo - 4.0).abs() < 1e-12);
        assert_eq!(
            multi_tenant_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks, 0).to_bits(),
            solo.to_bits()
        );
    }

    #[test]
    fn retransmit_factor_prices_planned_faults() {
        use crate::coordinator::fault::{FaultKind, FaultPlan, FaultSpec};
        // No faults / no transfers => neutral factor.
        assert_eq!(expected_retransmit_factor(0, 100), 1.0);
        assert_eq!(expected_retransmit_factor(5, 0), 1.0);
        // A plan with one drop and one corrupt over 100 transfers inflates
        // link time by exactly 2 extra crossings.
        let plan = FaultPlan::new(vec![
            FaultSpec::new(FaultKind::Drop).with_step(1),
            FaultSpec::new(FaultKind::Corrupt { bit: 3 }).with_step(2),
        ]);
        let extra = plan.planned_extra_transfers(3);
        assert_eq!(extra, 2);
        assert!((expected_retransmit_factor(extra, 100) - 1.02).abs() < 1e-12);
        // Budget 0 => nothing ever retransmits => neutral.
        assert_eq!(plan.planned_extra_transfers(0), 0);
    }

    #[test]
    fn link_codec_shrinks_only_the_transfers() {
        use crate::codec::CodecKind;
        let hw = HardwareProfile::workstation();
        let base = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        let mut coded = base.clone();
        coded.link_codec = Some(CodecKind::Bf16);
        let c0 = Costs::derive(&hw, &base);
        let c1 = Costs::derive(&hw, &coded);
        // Paper workloads already ship bf16 (bytes_per_param = 2), so the
        // explicit bf16 codec reprices transfers identically...
        assert!((c1.offload_layer_full - c0.offload_layer_full).abs() < 1e-12);
        // ...while sparse-int8 shrinks them and leaves compute untouched.
        coded.link_codec = Some(CodecKind::SparseInt8);
        let c2 = Costs::derive(&hw, &coded);
        let per_elem = CodecKind::SparseInt8.est_bytes_per_elem(1.0);
        let want = c0.offload_layer_full * per_elem / 2.0;
        assert!((c2.offload_layer_full - want).abs() / want < 1e-9, "{c2:?}");
        assert!((c2.offload_layer_sub / c0.offload_layer_sub - per_elem / 2.0).abs() < 1e-9);
        assert_eq!(c2.fwd_layer_gpu, c0.fwd_layer_gpu);
        assert_eq!(c2.upd_layer_cpu_full, c0.upd_layer_cpu_full);
        // And f32 re-encoding doubles them (2 -> 4 bytes/elem).
        coded.link_codec = Some(CodecKind::F32Raw);
        let c3 = Costs::derive(&hw, &coded);
        assert!((c3.offload_layer_full / c0.offload_layer_full - 2.0).abs() < 1e-9);
    }
}
