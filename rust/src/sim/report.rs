//! Timeline analysis: per-iteration time, resource busy fractions, and the
//! Fig. 2-style slowdown breakdown (GPU compute / non-overlapped Comm /
//! non-overlapped CPU / Other).

use std::collections::BTreeMap;

use super::engine::{Resource, Scheduled, ALL_RESOURCES};

/// Union of half-open intervals with total length computation.
#[derive(Debug, Default, Clone)]
pub struct IntervalSet {
    /// Sorted, disjoint (start, end).
    iv: Vec<(f64, f64)>,
}

impl IntervalSet {
    pub fn add(&mut self, start: f64, end: f64) {
        if end <= start {
            return;
        }
        self.iv.push((start, end));
        self.normalize();
    }

    fn normalize(&mut self) {
        self.iv.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(self.iv.len());
        for &(s, e) in &self.iv {
            if let Some(last) = out.last_mut() {
                if s <= last.1 {
                    last.1 = last.1.max(e);
                    continue;
                }
            }
            out.push((s, e));
        }
        self.iv = out;
    }

    pub fn total(&self) -> f64 {
        self.iv.iter().map(|(s, e)| e - s).sum()
    }

    /// Length of `self` minus (intersection with `other`).
    pub fn minus(&self, other: &IntervalSet) -> f64 {
        let mut uncovered = 0.0;
        for &(s, e) in &self.iv {
            let mut cur = s;
            for &(os, oe) in &other.iv {
                if oe <= cur {
                    continue;
                }
                if os >= e {
                    break;
                }
                if os > cur {
                    uncovered += (os - cur).min(e - cur);
                }
                cur = cur.max(oe);
                if cur >= e {
                    break;
                }
            }
            if cur < e {
                uncovered += e - cur;
            }
        }
        uncovered
    }

    pub fn union(&self, other: &IntervalSet) -> IntervalSet {
        let mut u = self.clone();
        for &(s, e) in &other.iv {
            u.add(s, e);
        }
        u
    }
}

/// Fig. 2-style breakdown, all normalized by GPU compute time.
#[derive(Debug, Clone)]
pub struct Breakdown {
    pub gpu: f64,
    /// Comm time not overlapped with GPU compute.
    pub comm: f64,
    /// CPU time not overlapped with GPU compute or comm.
    pub cpu: f64,
    /// Remaining idle time on the critical path.
    pub other: f64,
}

#[derive(Debug, Clone)]
pub struct IterReport {
    pub schedule: String,
    /// Steady-state time per iteration.
    pub iter_time: f64,
    /// Pure GPU fwd+bwd time per iteration (the Fig. 2 normalizer).
    pub gpu_compute: f64,
    pub makespan: f64,
    pub iters: usize,
    /// Busy seconds per resource per iteration.
    pub busy: BTreeMap<&'static str, f64>,
    pub breakdown: Breakdown,
}

impl IterReport {
    pub fn from_schedule(
        schedule: &str,
        sched: &[Scheduled],
        iters: usize,
        gpu_compute: f64,
        makespan: f64,
    ) -> IterReport {
        // Steady-state period: measured between the *starts* of successive
        // iterations' first forward task (tail tasks like low-priority
        // applies interleave across iteration boundaries, so end-based
        // measurement would under/over-count).
        let fwd0_start = |it: usize| -> Option<f64> {
            let name = format!("i{it}.fwd0");
            sched.iter().find(|s| s.spec.name == name).map(|s| s.start)
        };
        let iter_time = match (fwd0_start(1), fwd0_start(iters.saturating_sub(1))) {
            (Some(first), Some(last)) if iters > 2 && last > first => {
                (last - first) / (iters - 2) as f64
            }
            _ => makespan / iters as f64,
        };

        let mut sets: BTreeMap<Resource, IntervalSet> = BTreeMap::new();
        for s in sched {
            sets.entry(s.spec.resource).or_default().add(s.start, s.end);
        }
        let per_iter = |r: Resource| -> f64 {
            sets.get(&r).map(|s| s.total()).unwrap_or(0.0) / iters as f64
        };
        let mut busy = BTreeMap::new();
        for &r in &ALL_RESOURCES {
            let name = match r {
                Resource::Gpu => "gpu",
                Resource::Cpu => "cpu",
                Resource::H2D => "h2d",
                Resource::D2H => "d2h",
            };
            busy.insert(name, per_iter(r));
        }

        let empty = IntervalSet::default();
        let gpu_set = sets.get(&Resource::Gpu).unwrap_or(&empty);
        let comm_set = sets
            .get(&Resource::H2D)
            .unwrap_or(&empty)
            .union(sets.get(&Resource::D2H).unwrap_or(&empty));
        let cpu_set = sets.get(&Resource::Cpu).unwrap_or(&empty);

        let gpu_busy = gpu_set.total() / iters as f64;
        let comm_exposed = comm_set.minus(gpu_set) / iters as f64;
        let cpu_exposed = cpu_set.minus(&gpu_set.union(&comm_set)) / iters as f64;
        let other =
            (iter_time - gpu_busy - comm_exposed - cpu_exposed).max(0.0);

        IterReport {
            schedule: schedule.to_string(),
            iter_time,
            gpu_compute,
            makespan,
            iters,
            busy,
            breakdown: Breakdown {
                gpu: gpu_busy,
                comm: comm_exposed,
                cpu: cpu_exposed,
                other,
            },
        }
    }

    pub fn slowdown(&self) -> f64 {
        self.iter_time / self.gpu_compute
    }

    pub fn print_row(&self) {
        let b = &self.breakdown;
        println!(
            "{:16} iter {:>9} slowdown {:>5.2}x | gpu {:>8} comm+ {:>8} cpu+ {:>8} other {:>8}",
            self.schedule,
            crate::util::human_secs(self.iter_time),
            self.slowdown(),
            crate::util::human_secs(b.gpu),
            crate::util::human_secs(b.comm),
            crate::util::human_secs(b.cpu),
            crate::util::human_secs(b.other),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_union_and_total() {
        let mut s = IntervalSet::default();
        s.add(0.0, 1.0);
        s.add(0.5, 2.0); // merges
        s.add(3.0, 4.0);
        assert!((s.total() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn interval_minus() {
        let mut a = IntervalSet::default();
        a.add(0.0, 10.0);
        let mut b = IntervalSet::default();
        b.add(2.0, 4.0);
        b.add(6.0, 7.0);
        // 10 - 2 - 1 = 7 uncovered.
        assert!((a.minus(&b) - 7.0).abs() < 1e-12);
        // Empty minus anything is 0.
        assert_eq!(IntervalSet::default().minus(&a), 0.0);
        // Disjoint: full length.
        let mut c = IntervalSet::default();
        c.add(20.0, 21.0);
        assert!((c.minus(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn breakdown_sums_to_iter_time() {
        use crate::model::memory::PaperModel;
        use crate::sim::cost_model::{HardwareProfile, Workload};
        use crate::sim::schedules::{build_schedule, ScheduleKind};
        let hw = HardwareProfile::workstation();
        let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        for kind in [ScheduleKind::Zero, ScheduleKind::LspLayerwise] {
            let rep = build_schedule(kind, &hw, &w, 3).unwrap();
            let b = &rep.breakdown;
            let sum = b.gpu + b.comm + b.cpu + b.other;
            // Busy fractions are per-iteration averages; with steady-state
            // iter_time they should roughly cover it (within the cold-start
            // fringe).
            assert!(
                sum >= rep.iter_time * 0.7 && sum <= rep.iter_time * 1.4 + 1e-9,
                "{kind:?}: breakdown sum {sum} vs iter {}",
                rep.iter_time
            );
        }
    }
}
