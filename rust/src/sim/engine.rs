//! Exact list-scheduling DES over single-server resources.
//!
//! Tasks have a fixed duration, a resource, dependencies, and a priority.
//! Each resource serves one task at a time; among ready tasks it picks the
//! lowest priority value first (ties: lowest id — submission order, i.e.
//! FCFS).  The LCFS phase of the paper's Alg. 3 is expressed by assigning
//! *descending* priorities past the TransitionLayer.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Resource {
    Gpu,
    Cpu,
    H2D,
    D2H,
}

pub const ALL_RESOURCES: [Resource; 4] =
    [Resource::Gpu, Resource::Cpu, Resource::H2D, Resource::D2H];

pub type TaskId = usize;

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    pub resource: Resource,
    pub duration: f64,
    pub deps: Vec<TaskId>,
    pub priority: i64,
}

#[derive(Debug, Clone)]
pub struct Scheduled {
    pub spec: TaskSpec,
    pub start: f64,
    pub end: f64,
}

impl Scheduled {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

#[derive(Debug, Default)]
pub struct Sim {
    tasks: Vec<TaskSpec>,
}

impl Sim {
    pub fn new() -> Sim {
        Sim { tasks: Vec::new() }
    }

    pub fn add(&mut self, name: impl Into<String>, resource: Resource, duration: f64,
               deps: &[TaskId]) -> TaskId {
        self.add_prio(name, resource, duration, deps, 0)
    }

    pub fn add_prio(&mut self, name: impl Into<String>, resource: Resource, duration: f64,
                    deps: &[TaskId], priority: i64) -> TaskId {
        assert!(duration >= 0.0, "negative duration");
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dep {d} of task {id} not yet defined (DAG required)");
        }
        self.tasks.push(TaskSpec {
            name: name.into(),
            resource,
            duration,
            deps: deps.to_vec(),
            priority,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Task specs (for external schedule validation / property tests).
    pub fn tasks(&self) -> &[TaskSpec] {
        &self.tasks
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Run the simulation; returns the per-task schedule.
    ///
    /// Event-driven list scheduling: whenever a resource is free and has
    /// ready tasks, it starts the best-priority one.  This is exact for
    /// fixed durations and single-server resources.
    pub fn run(&self) -> Result<Vec<Scheduled>> {
        let n = self.tasks.len();
        let mut done_at: Vec<Option<f64>> = vec![None; n];
        let mut started: Vec<bool> = vec![false; n];
        let mut sched: Vec<Option<Scheduled>> = vec![None; n];
        let mut res_free: BTreeMap<Resource, f64> =
            ALL_RESOURCES.iter().map(|&r| (r, 0.0)).collect();
        let mut remaining = n;

        while remaining > 0 {
            // Collect ready tasks (deps done, not started) with ready time.
            let mut progressed = false;
            // For each resource, choose the next task to run.
            for &res in &ALL_RESOURCES {
                loop {
                    let free_at = res_free[&res];
                    // Candidates on this resource whose deps are all done.
                    let mut best: Option<(i64, f64, TaskId)> = None;
                    let mut earliest_ready = f64::INFINITY;
                    for (id, t) in self.tasks.iter().enumerate() {
                        if started[id] || t.resource != res {
                            continue;
                        }
                        let ready = t.deps.iter().try_fold(0f64, |acc, &d| {
                            done_at[d].map(|e| acc.max(e))
                        });
                        let Some(ready) = ready else { continue };
                        earliest_ready = earliest_ready.min(ready);
                        // The resource picks among tasks ready by the time
                        // it is free; if none, it idles until the earliest.
                        let eff_ready = ready.max(free_at);
                        let key = (t.priority, eff_ready, id);
                        if best.is_none_or(|b| key < b) {
                            best = Some(key);
                        }
                    }
                    let Some((_, _, id)) = best else { break };
                    // Only start if the task is ready at or before the time
                    // the resource becomes free OR nothing else will beat it
                    // (single-server: we can commit because priorities are
                    // static and all ready times are known only when deps
                    // finish — we conservatively re-evaluate each loop).
                    let t = &self.tasks[id];
                    let ready = t
                        .deps
                        .iter()
                        .map(|&d| done_at[d].unwrap())
                        .fold(0f64, f64::max);
                    let start = ready.max(free_at);
                    // Check no *other* unfinished task on this resource with
                    // better priority could become ready before `start`:
                    // since we don't know future completion times of other
                    // resources exactly here, we only start the task if all
                    // better-priority tasks on this resource already started.
                    let blocked = self.tasks.iter().enumerate().any(|(oid, ot)| {
                        oid != id
                            && !started[oid]
                            && ot.resource == res
                            && (ot.priority, oid) < (t.priority, id)
                            && ot.deps.iter().all(|&d| {
                                // could it be ready before we would start?
                                done_at[d].map(|e| e <= start).unwrap_or(false)
                            })
                    });
                    if blocked {
                        break;
                    }
                    started[id] = true;
                    let end = start + t.duration;
                    done_at[id] = Some(end);
                    res_free.insert(res, end);
                    sched[id] = Some(Scheduled { spec: t.clone(), start, end });
                    remaining -= 1;
                    progressed = true;
                }
            }
            if !progressed && remaining > 0 {
                // Deadlock means a dependency cycle or a task waiting on an
                // unfinishable dep — with the conservative `blocked` rule we
                // may also stall; fall back to starting the globally
                // earliest-ready task.
                let mut cand: Option<(f64, i64, TaskId)> = None;
                for (id, t) in self.tasks.iter().enumerate() {
                    if started[id] {
                        continue;
                    }
                    let ready = t.deps.iter().try_fold(0f64, |acc, &d| {
                        done_at[d].map(|e| acc.max(e))
                    });
                    let Some(ready) = ready else { continue };
                    let start = ready.max(res_free[&t.resource]);
                    let key = (start, t.priority, id);
                    if cand.is_none_or(|c| key < c) {
                        cand = Some(key);
                    }
                }
                let Some((_, _, id)) = cand else {
                    bail!("simulation deadlock: dependency cycle");
                };
                let t = &self.tasks[id];
                let ready =
                    t.deps.iter().map(|&d| done_at[d].unwrap()).fold(0f64, f64::max);
                let start = ready.max(res_free[&t.resource]);
                let end = start + t.duration;
                started[id] = true;
                done_at[id] = Some(end);
                res_free.insert(t.resource, end);
                sched[id] = Some(Scheduled { spec: t.clone(), start, end });
                remaining -= 1;
            }
        }
        Ok(sched.into_iter().map(Option::unwrap).collect())
    }
}

/// Makespan of a schedule.
pub fn makespan(sched: &[Scheduled]) -> f64 {
    sched.iter().map(|s| s.end).fold(0.0, f64::max)
}

/// Verify the invariants every valid schedule must satisfy; used by the
/// property tests. Returns an error message on violation.
pub fn validate(tasks: &[TaskSpec], sched: &[Scheduled]) -> std::result::Result<(), String> {
    if tasks.len() != sched.len() {
        return Err("length mismatch".into());
    }
    // Dependencies respected.
    for (id, s) in sched.iter().enumerate() {
        for &d in &tasks[id].deps {
            if sched[d].end > s.start + 1e-9 {
                return Err(format!(
                    "task {} starts {} before dep {} ends {}",
                    s.spec.name, s.start, sched[d].spec.name, sched[d].end
                ));
            }
        }
    }
    // No overlap per resource.
    for &res in &ALL_RESOURCES {
        let mut iv: Vec<(f64, f64, &str)> = sched
            .iter()
            .filter(|s| s.spec.resource == res && s.spec.duration > 0.0)
            .map(|s| (s.start, s.end, s.spec.name.as_str()))
            .collect();
        iv.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in iv.windows(2) {
            if w[1].0 < w[0].1 - 1e-9 {
                return Err(format!(
                    "resource {res:?}: {} [{};{}] overlaps {} [{};{}]",
                    w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_sequential() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Gpu, 1.0, &[]);
        let b = sim.add("b", Resource::Gpu, 2.0, &[a]);
        let _c = sim.add("c", Resource::Gpu, 3.0, &[b]);
        let s = sim.run().unwrap();
        assert_eq!(makespan(&s), 6.0);
        validate(&sim.tasks, &s).unwrap();
    }

    #[test]
    fn independent_resources_overlap() {
        let mut sim = Sim::new();
        sim.add("gpu", Resource::Gpu, 2.0, &[]);
        sim.add("d2h", Resource::D2H, 2.0, &[]);
        sim.add("h2d", Resource::H2D, 2.0, &[]);
        sim.add("cpu", Resource::Cpu, 2.0, &[]);
        let s = sim.run().unwrap();
        assert_eq!(makespan(&s), 2.0, "full duplex + parallel compute");
        validate(&sim.tasks, &s).unwrap();
    }

    #[test]
    fn dependency_across_resources() {
        let mut sim = Sim::new();
        let bwd = sim.add("bwd", Resource::Gpu, 1.0, &[]);
        let off = sim.add("off", Resource::D2H, 0.5, &[bwd]);
        let upd = sim.add("upd", Resource::Cpu, 1.0, &[off]);
        let up = sim.add("up", Resource::H2D, 0.5, &[upd]);
        let _apply = sim.add("apply", Resource::Gpu, 0.1, &[up]);
        let s = sim.run().unwrap();
        assert!((makespan(&s) - 3.1).abs() < 1e-9);
        validate(&sim.tasks, &s).unwrap();
    }

    #[test]
    fn priority_orders_queue() {
        let mut sim = Sim::new();
        // Both ready at t=0 on the same resource; lower priority value first.
        sim.add_prio("late", Resource::Gpu, 1.0, &[], 10);
        sim.add_prio("early", Resource::Gpu, 1.0, &[], 1);
        let s = sim.run().unwrap();
        let early = s.iter().find(|x| x.spec.name == "early").unwrap();
        let late = s.iter().find(|x| x.spec.name == "late").unwrap();
        assert!(early.start < late.start);
    }

    #[test]
    fn zero_duration_tasks_ok() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Gpu, 0.0, &[]);
        let b = sim.add("b", Resource::Gpu, 1.0, &[a]);
        let s = sim.run().unwrap();
        assert_eq!(s[b].end, 1.0);
    }
}
