//! Task-DAG builders for every offloading pipeline of Fig. 3 plus the
//! no-offload native baseline and LSP ablations.
//!
//! Each builder lays out `iters` back-to-back iterations so steady-state
//! per-iteration time can be measured without the cold-start transient.

use anyhow::Result;

use super::cost_model::{Costs, HardwareProfile, Workload};
use super::engine::{makespan, Resource, Sim, TaskId};
use super::report::IterReport;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Everything on the GPU (assumes infinite GPU memory) — the paper's
    /// "native" upper bound in Fig. 6.
    Native,
    /// Swap-only offloading (Fig. 3c): all compute on the GPU, memory
    /// streamed in/out; bounded below by the Observation.
    SwapOnly,
    /// Zero-Offload (Alg. 2 / Fig. 3a).
    Zero,
    /// Zero with delayed parameter update (Fig. 3b): previous iteration's
    /// UPD overlaps current FWD+BWD; the two PCIe directions are serialized
    /// (the paper notes Zero cannot parallelize them without extra buffers).
    ZeroDelayed,
    /// Zero + our layer-wise schedule but *without* subspace compression
    /// (the "+layerwise" ablation column of Fig. 6).
    ZeroLayerwise,
    /// Full LSP-Offload (Alg. 3 / Fig. 3d): compress + layer-wise overlap
    /// with the FCFS->LCFS transition heuristic.
    LspLayerwise,
    /// Stall-free LSP (`async-lsp`): the top-rho important slice applies
    /// on-GPU right after each layer's backward; only the (1-rho) tail
    /// crosses the links, and a fwd gates on the tail apply from S+1
    /// iterations back (bounded staleness) instead of the previous one.
    AsyncLsp,
    /// Multi-tenant arbitration (`Workload::tenants` = K): K independent
    /// lsp-layerwise tenant replicas — task names prefixed `t{k}.` — share
    /// the one GPU driver, both links and the CPU updater, modeling the
    /// runtime's [`crate::coordinator::arbiter::Arbiter`].  `tenants = 1`
    /// degenerates exactly to [`ScheduleKind::LspLayerwise`].
    MultiTenant,
    /// Forward-only serving (`--schedule infer` / `serve`): host-resident
    /// weights stream h2d per layer with `Workload::prefetch_depth`
    /// in-flight streams (the modeled device weight budget), each layer's
    /// forward gating on its own stream — the DES model of the runtime's
    /// [`crate::coordinator::infer::InferEngine`].  `prefetch_depth = 1`
    /// serializes stream and compute (the closed form
    /// [`crate::sim::cost_model::eq_infer_iter`]'s serial corner);
    /// `>= 2` overlaps layer l's forward with layer l+1's stream.
    Infer,
}

impl ScheduleKind {
    pub fn by_name(s: &str) -> Option<ScheduleKind> {
        match s {
            "native" => Some(ScheduleKind::Native),
            "swap" | "swap-only" => Some(ScheduleKind::SwapOnly),
            "zero" => Some(ScheduleKind::Zero),
            "zero-delayed" | "delayed" => Some(ScheduleKind::ZeroDelayed),
            "zero-layerwise" | "layerwise" => Some(ScheduleKind::ZeroLayerwise),
            "lsp" | "lsp-layerwise" => Some(ScheduleKind::LspLayerwise),
            "async-lsp" | "async" => Some(ScheduleKind::AsyncLsp),
            "multi-tenant" | "multi" | "tenants" => Some(ScheduleKind::MultiTenant),
            "infer" | "serve" => Some(ScheduleKind::Infer),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Native => "native",
            ScheduleKind::SwapOnly => "swap-only",
            ScheduleKind::Zero => "zero",
            ScheduleKind::ZeroDelayed => "zero-delayed",
            ScheduleKind::ZeroLayerwise => "zero-layerwise",
            ScheduleKind::LspLayerwise => "lsp-layerwise",
            ScheduleKind::AsyncLsp => "async-lsp",
            ScheduleKind::MultiTenant => "multi-tenant",
            ScheduleKind::Infer => "infer",
        }
    }

    /// The DES schedule that models a given runtime training policy — the
    /// sim-overlay tracks of `train --trace-out` predict this kind's task
    /// timeline next to the measured one.  `None` for policies the DES has
    /// no model of (LoRA / GaLore train entirely on-GPU).
    pub fn for_policy(policy: &str) -> Option<ScheduleKind> {
        match policy {
            "native" => Some(ScheduleKind::Native),
            "zero" => Some(ScheduleKind::Zero),
            "lsp" => Some(ScheduleKind::LspLayerwise),
            "async-lsp" => Some(ScheduleKind::AsyncLsp),
            _ => None,
        }
    }

    pub const ALL: [ScheduleKind; 9] = [
        ScheduleKind::Native,
        ScheduleKind::SwapOnly,
        ScheduleKind::Zero,
        ScheduleKind::ZeroDelayed,
        ScheduleKind::ZeroLayerwise,
        ScheduleKind::LspLayerwise,
        ScheduleKind::AsyncLsp,
        ScheduleKind::MultiTenant,
        ScheduleKind::Infer,
    ];
}

/// Build the task DAG for `kind` without running it (property tests).
pub fn build_sim(kind: ScheduleKind, hw: &HardwareProfile, w: &Workload, iters: usize) -> Sim {
    let c = Costs::derive(hw, w);
    let mut sim = Sim::new();
    match kind {
        ScheduleKind::Native => native(&mut sim, &c, w, iters),
        ScheduleKind::SwapOnly => swap_only(&mut sim, &c, hw, w, iters),
        ScheduleKind::Zero => zero(&mut sim, &c, w, iters, false),
        ScheduleKind::ZeroDelayed => zero_delayed(&mut sim, &c, w, iters),
        ScheduleKind::ZeroLayerwise => layerwise(&mut sim, &c, w, iters, false),
        ScheduleKind::LspLayerwise => layerwise(&mut sim, &c, w, iters, true),
        ScheduleKind::AsyncLsp => layerwise_async(&mut sim, &c, w, iters),
        ScheduleKind::MultiTenant => multi_tenant(&mut sim, &c, w, iters),
        ScheduleKind::Infer => infer(&mut sim, &c, w, iters),
    }
    sim
}

/// Build and run `iters` iterations of `kind`; returns the report.
pub fn build_schedule(
    kind: ScheduleKind,
    hw: &HardwareProfile,
    w: &Workload,
    iters: usize,
) -> Result<IterReport> {
    let c = Costs::derive(hw, w);
    let mut sim = Sim::new();
    match kind {
        ScheduleKind::Native => native(&mut sim, &c, w, iters),
        ScheduleKind::SwapOnly => swap_only(&mut sim, &c, hw, w, iters),
        ScheduleKind::Zero => zero(&mut sim, &c, w, iters, false),
        ScheduleKind::ZeroDelayed => zero_delayed(&mut sim, &c, w, iters),
        ScheduleKind::ZeroLayerwise => layerwise(&mut sim, &c, w, iters, false),
        ScheduleKind::LspLayerwise => layerwise(&mut sim, &c, w, iters, true),
        ScheduleKind::AsyncLsp => layerwise_async(&mut sim, &c, w, iters),
        ScheduleKind::MultiTenant => multi_tenant(&mut sim, &c, w, iters),
        ScheduleKind::Infer => infer(&mut sim, &c, w, iters),
    }
    let sched = sim.run()?;
    // Multi-tenant lays out K replicas of the per-iteration work, so the
    // aggregate GPU-compute baseline scales with the tenant count (the
    // slowdown column stays total-work / capacity).
    let replicas = if kind == ScheduleKind::MultiTenant { w.tenants.max(1) } else { 1 };
    // Forward-only serving has no backward: its GPU-compute baseline is
    // the forward path alone, not `Costs::gpu_compute` (fwd + bwd).
    let gpu_compute = if kind == ScheduleKind::Infer {
        c.fwd_layer_gpu * w.n_layers as f64
    } else {
        c.gpu_compute(w.n_layers) * replicas as f64
    };
    Ok(IterReport::from_schedule(kind.name(), &sched, iters, gpu_compute, makespan(&sched)))
}

fn native(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize) {
    let n = w.n_layers;
    let mut prev: Option<TaskId> = None;
    for it in 0..iters {
        for l in 0..n {
            let deps: Vec<_> = prev.into_iter().collect();
            prev = Some(sim.add(format!("i{it}.fwd{l}"), Resource::Gpu, c.fwd_layer_gpu, &deps));
        }
        for l in (0..n).rev() {
            let deps: Vec<_> = prev.into_iter().collect();
            prev = Some(sim.add(format!("i{it}.bwd{l}"), Resource::Gpu, c.bwd_layer_gpu, &deps));
        }
        // On-GPU fused Adam: memory-bandwidth-bound.
        let deps: Vec<_> = prev.into_iter().collect();
        prev = Some(sim.add(
            format!("i{it}.upd"),
            Resource::Gpu,
            c.upd_layer_gpu_native * n as f64,
            &deps,
        ));
    }
}

fn swap_only(sim: &mut Sim, c: &Costs, hw: &HardwareProfile, w: &Workload, iters: usize) {
    // All compute on GPU; every iteration must move >= M_tot - M_gpu bytes
    // (Observation). We stream it as per-layer h2d chunks feeding compute.
    let n = w.n_layers;
    // M_tot = weights + grads + optimizer state (fp16 x4 per param) plus
    // activations (no checkpointing in swap-type systems): ~12 floats per
    // token per hidden unit per layer.
    let hidden = ((w.params_per_layer() / 12) as f64).sqrt();
    let act_bytes =
        (w.tokens as f64) * (n as f64) * 12.0 * hidden * w.bytes_per_param as f64;
    let m_tot = (w.params * w.bytes_per_param) as f64 * 4.0 + act_bytes;
    let deficit = (m_tot - hw.gpu_mem_bytes as f64).max(0.0);
    // The Observation: every byte beyond GPU memory crosses the link *each
    // way* every iteration (fetched before use, evicted after update).
    // Swap traffic is bulk + unpinned: the paper's own 40 GB -> 5.33 s
    // arithmetic implies ~7.5 GB/s effective (see HardwareProfile).
    let per_layer_in = deficit / (n as f64) / hw.swap_bytes_per_s;
    let per_layer_out = deficit / (n as f64) / hw.swap_bytes_per_s;
    let mut prev: Option<TaskId> = None;
    for it in 0..iters {
        let mut swaps = Vec::new();
        for l in 0..n {
            let sw =
                sim.add(format!("i{it}.swapin{l}"), Resource::H2D, per_layer_in, &[]);
            let mut deps: Vec<_> = prev.into_iter().collect();
            deps.push(sw);
            prev = Some(sim.add(format!("i{it}.fwd{l}"), Resource::Gpu, c.fwd_layer_gpu, &deps));
            swaps.push(sw);
        }
        for l in (0..n).rev() {
            let sw =
                sim.add(format!("i{it}.swapout{l}"), Resource::D2H, per_layer_out, &[]);
            let mut deps: Vec<_> = prev.into_iter().collect();
            deps.push(sw);
            prev = Some(sim.add(format!("i{it}.bwd{l}"), Resource::Gpu, c.bwd_layer_gpu, &deps));
        }
        let deps: Vec<_> = prev.into_iter().collect();
        prev = Some(sim.add(
            format!("i{it}.upd"),
            Resource::Gpu,
            c.upd_layer_gpu_native * n as f64,
            &deps,
        ));
    }
}

/// Zero-Offload, Alg. 2: full gradients offloaded as bwd proceeds; the CPU
/// update starts after the backward finishes (optimizer step is atomic over
/// the full parameter set in Zero's implementation); the delta upload
/// overlaps the CPU update of later chunks; GPU applies deltas at the end.
///
/// With sub-layer chunking (`Workload::link_chunk_elems > 0`) the model
/// follows the runtime instead of the paper's atomic step: each layer's
/// offload splits into wire chunks and the CPU Adam runs per chunk *as
/// chunks arrive* (the chunked `CpuUpdater` semantics), with each chunk's
/// delta upload pipelining behind it.
fn zero(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize, _delayed: bool) {
    let n = w.n_layers;
    let cch = w.layer_chunks(false) as usize;
    let mut apply_done: Option<TaskId> = None;
    for it in 0..iters {
        let mut prev = apply_done;
        for l in 0..n {
            let deps: Vec<_> = prev.into_iter().collect();
            prev = Some(sim.add(format!("i{it}.fwd{l}"), Resource::Gpu, c.fwd_layer_gpu, &deps));
        }
        let mut offloads = Vec::new();
        let mut last_off: Option<TaskId> = None;
        let mut bwd_last = prev.unwrap();
        for l in (0..n).rev() {
            let bwd = sim.add(
                format!("i{it}.bwd{l}"),
                Resource::Gpu,
                c.bwd_layer_gpu,
                &[bwd_last],
            );
            bwd_last = bwd;
            // Gradient offload overlaps deeper layers' bwd (FCFS on D2H),
            // split into `cch` wire chunks per layer when chunking is on.
            for ch in 0..cch {
                let mut odeps = vec![bwd];
                odeps.extend(last_off);
                let name = if cch == 1 {
                    format!("i{it}.off{l}")
                } else {
                    format!("i{it}.off{l}.c{ch}")
                };
                let off =
                    sim.add(name, Resource::D2H, c.offload_layer_full / cch as f64, &odeps);
                last_off = Some(off);
                offloads.push(off);
            }
        }
        let mut upload_last: Option<TaskId> = None;
        let mut uploads = Vec::new();
        let mut upd_prev: Option<TaskId> = None;
        // Branch on the ACTUAL split, not the budget: a chunk budget large
        // enough that no layer splits (cch == 1) must degenerate to the
        // atomic-step builder exactly — the DES counterpart of the
        // runtime's n_chunks = 1 bit-identity invariant.
        if cch == 1 {
            // CPU update: starts when backward AND all offloads are done
            // (Zero's fused CPU Adam runs over the whole gradient buffer),
            // chunked at layer granularity so uploads can overlap
            // subsequent layers' update.
            let mut upd_deps: Vec<TaskId> = offloads.clone();
            upd_deps.push(bwd_last);
            for ch in 0..n {
                let mut deps = if ch == 0 { upd_deps.clone() } else { vec![] };
                deps.extend(upd_prev);
                let upd = sim.add(
                    format!("i{it}.upd{ch}"),
                    Resource::Cpu,
                    c.upd_layer_cpu_full,
                    &deps,
                );
                upd_prev = Some(upd);
                let mut udeps = vec![upd];
                udeps.extend(upload_last);
                let up = sim.add(
                    format!("i{it}.up{ch}"),
                    Resource::H2D,
                    c.upload_layer_full,
                    &udeps,
                );
                upload_last = Some(up);
                uploads.push(up);
            }
        } else {
            // Sub-layer chunking: fused Adam per arriving chunk, delta
            // upload pipelining behind it — the chunked runtime semantics.
            // Sub-threshold chunks run the runtime's Adam single-threaded
            // (`optim::PAR_ADAM_MIN_LEN`), so each chunk's share carries
            // the updater penalty.
            for (k, &off) in offloads.iter().enumerate() {
                let mut deps = vec![off];
                deps.extend(upd_prev);
                let upd = sim.add(
                    format!("i{it}.upd.c{k}"),
                    Resource::Cpu,
                    c.upd_layer_cpu_full * c.upd_chunk_penalty / cch as f64,
                    &deps,
                );
                upd_prev = Some(upd);
                let mut udeps = vec![upd];
                udeps.extend(upload_last);
                let up = sim.add(
                    format!("i{it}.up.c{k}"),
                    Resource::H2D,
                    c.upload_layer_full / cch as f64,
                    &udeps,
                );
                upload_last = Some(up);
                uploads.push(up);
            }
        }
        let apply = sim.add(
            format!("i{it}.apply"),
            Resource::Gpu,
            c.apply_layer_full_gpu * n as f64,
            &uploads,
        );
        apply_done = Some(apply);
    }
}

/// Zero with delayed parameter update (Fig. 3b): iteration t's CPU update +
/// comm run concurrently with iteration t+1's fwd/bwd (stale weights).
/// Paper: to avoid extra buffers, d2h and h2d cannot be parallelized —
/// modelled by routing *both* directions through the H2D server.
fn zero_delayed(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize) {
    let n = w.n_layers;
    let mut prev_upd_chain: Option<TaskId> = None;
    // One-step staleness: iteration t's fwd/bwd overlaps the CPU update of
    // iteration t-1's gradients, so fwd(t) only waits for the *t-2* delta
    // upload (the paper's accuracy-affecting trade).
    let mut prev_iter_uploads: Option<TaskId> = None;
    let mut prev2_iter_uploads: Option<TaskId> = None;
    for it in 0..iters {
        let gate = prev2_iter_uploads;
        let mut prev: Option<TaskId> = None;
        for l in 0..n {
            let mut deps: Vec<_> = prev.into_iter().collect();
            if l == 0 {
                deps.extend(gate);
            }
            prev = Some(sim.add(format!("i{it}.fwd{l}"), Resource::Gpu, c.fwd_layer_gpu, &deps));
        }
        let mut bwd_last = prev.unwrap();
        let mut offloads = Vec::new();
        let mut last_off = prev_upd_chain; // serialize with previous comm
        for l in (0..n).rev() {
            let bwd = sim.add(
                format!("i{it}.bwd{l}"),
                Resource::Gpu,
                c.bwd_layer_gpu,
                &[bwd_last],
            );
            bwd_last = bwd;
            let mut odeps = vec![bwd];
            odeps.extend(last_off);
            let off = sim.add(
                format!("i{it}.off{l}"),
                Resource::H2D, // shared channel (no duplex in delayed mode)
                c.offload_layer_full,
                &odeps,
            );
            last_off = Some(off);
            offloads.push(off);
        }
        // Delayed update: runs after offloads but does NOT gate next fwd.
        let mut upd_prev: Option<TaskId> = None;
        let mut up_last: Option<TaskId> = None;
        for ch in 0..n {
            let mut deps: Vec<TaskId> = if ch == 0 { offloads.clone() } else { vec![] };
            deps.extend(upd_prev);
            let upd = sim.add(
                format!("i{it}.upd{ch}"),
                Resource::Cpu,
                c.upd_layer_cpu_full,
                &deps,
            );
            upd_prev = Some(upd);
            let mut udeps = vec![upd];
            udeps.extend(up_last);
            up_last = Some(sim.add(
                format!("i{it}.up{ch}"),
                Resource::H2D,
                c.upload_layer_full,
                &udeps,
            ));
        }
        prev_upd_chain = up_last;
        prev2_iter_uploads = prev_iter_uploads;
        prev_iter_uploads = up_last;
    }
}

/// Build one layer's offload -> CPU-update -> upload tail, split into
/// `cch` sub-layer chunk pipelines (PIPO-style) — the chunk modeling
/// SHARED by the `layerwise` and `layerwise_async` builders so the two
/// schedules cannot drift.  Per-chunk costs are the layer totals split
/// evenly, every chunk shares the layer's `prio` (the priority scheme is
/// the caller's), and the returned uploads are what the layer's apply
/// gates on.  `cch = 1` reproduces the original whole-layer triple with
/// the original unsuffixed task names.
#[allow(clippy::too_many_arguments)]
fn chunked_layer_tail(
    sim: &mut Sim,
    pfx: &str,
    it: usize,
    l: usize,
    dep: TaskId,
    off_t: f64,
    upd_t: f64,
    up_t: f64,
    cch: usize,
    prio: i64,
) -> Vec<TaskId> {
    let mut ups = Vec::with_capacity(cch);
    for ch in 0..cch {
        let suffix = if cch == 1 { String::new() } else { format!(".c{ch}") };
        let off = sim.add_prio(
            format!("{pfx}i{it}.off{l}{suffix}"),
            Resource::D2H,
            off_t / cch as f64,
            &[dep],
            prio,
        );
        let upd = sim.add_prio(
            format!("{pfx}i{it}.upd{l}{suffix}"),
            Resource::Cpu,
            upd_t / cch as f64,
            &[off],
            prio,
        );
        let up = sim.add_prio(
            format!("{pfx}i{it}.up{l}{suffix}"),
            Resource::H2D,
            up_t / cch as f64,
            &[upd],
            prio,
        );
        ups.push(up);
    }
    ups
}

/// Layer-wise schedule (Alg. 3). With `compress = true` this is full
/// LSP-Offload (subspace-sized comm + CPU update, plus GPU compress/apply);
/// with `false` it is the "+layerwise" Fig. 6 ablation over full gradients.
fn layerwise(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize, compress: bool) {
    layerwise_prefixed(sim, c, w, iters, compress, "");
}

/// [`layerwise`] with every task name prefixed `pfx` — the per-tenant
/// replica the [`multi_tenant`] builder lays out K times over the shared
/// resources.  An empty prefix reproduces the solo task names exactly, so
/// the `tenants = 1` degeneracy holds down to the task list.
fn layerwise_prefixed(
    sim: &mut Sim,
    c: &Costs,
    w: &Workload,
    iters: usize,
    compress: bool,
    pfx: &str,
) {
    let n = w.n_layers;
    let (off_t, up_t, upd_t) = if compress {
        (c.offload_layer_sub, c.upload_layer_sub, c.upd_layer_cpu_sub)
    } else {
        (c.offload_layer_full, c.upload_layer_full, c.upd_layer_cpu_full)
    };
    // TransitionLayer heuristic (paper appendix): deepest layer whose
    // pipeline tail could block the next iteration's first fwd.
    let tail = off_t + up_t + upd_t;
    let per = off_t.max(up_t).max(upd_t).max(1e-12);
    let bwd_total = c.bwd_layer_gpu * n as f64;
    let transition = ((bwd_total - tail) / per).floor().clamp(0.0, n as f64) as usize;

    // apply_done[l] = apply task of layer l from the previous iteration.
    let mut apply_done: Vec<Option<TaskId>> = vec![None; n];
    for it in 0..iters {
        let mut prev: Option<TaskId> = None;
        for l in 0..n {
            // Wait for event e_l: fwd after this layer's params updated.
            let mut deps: Vec<_> = prev.into_iter().collect();
            deps.extend(apply_done[l]);
            prev = Some(sim.add(
                format!("{pfx}i{it}.fwd{l}"),
                Resource::Gpu,
                c.fwd_layer_gpu,
                &deps,
            ));
        }
        let mut bwd_prev = prev.unwrap();
        for l in (0..n).rev() {
            let bwd = sim.add(
                format!("{pfx}i{it}.bwd{l}"),
                Resource::Gpu,
                c.bwd_layer_gpu,
                &[bwd_prev],
            );
            bwd_prev = bwd;
            // FCFS first (deep layers first-come), LCFS past the transition:
            // shallower layers jump the queue so the next iteration's first
            // fwd is unblocked sooner. Lower priority value = served first.
            let depth = n - 1 - l; // order of arrival in the backward pass
            let prio = if depth < transition { depth as i64 } else { -(l as i64 + 1) };
            let (cmp, compress_dep) = if compress {
                let t = sim.add(
                    format!("{pfx}i{it}.cmp{l}"),
                    Resource::Gpu,
                    c.compress_layer_gpu,
                    &[bwd],
                );
                (Some(t), t)
            } else {
                (None, bwd)
            };
            let _ = cmp;
            // Sub-layer chunking (PIPO-style): the layer's offload ->
            // update -> upload tail splits into `cch` chunk pipelines, so
            // the CPU updater starts before the layer's gradient has fully
            // crossed and the upload starts before its delta is fully
            // produced.  `cch = 1` (chunking off) is the original
            // whole-layer triple.  All chunks share the layer's priority,
            // so the FCFS->LCFS transition interleaves chunks of different
            // layers on the links.
            let cch = w.layer_chunks(compress) as usize;
            // A real split (cch > 1) drops the runtime's fused Adam below
            // its parallel-dispatch threshold: price the updater with the
            // chunk penalty.  cch == 1 must stay bit-exact unchunked.
            let upd_eff = if cch > 1 { upd_t * c.upd_chunk_penalty } else { upd_t };
            let ups = chunked_layer_tail(
                sim,
                pfx,
                it,
                l,
                compress_dep,
                off_t,
                upd_eff,
                up_t,
                cch,
                prio,
            );
            let apply_cost = if compress { c.apply_layer_gpu } else { c.apply_layer_full_gpu };
            // Apply on GPU; low priority so it never preempts fwd/bwd order
            // but must finish before next iteration's fwd of this layer.
            // The layer event gates on the WHOLE layer, so the apply waits
            // for every chunk's upload.
            let apply = sim.add_prio(
                format!("{pfx}i{it}.apply{l}"),
                Resource::Gpu,
                apply_cost,
                &ups,
                1000 + l as i64,
            );
            apply_done[l] = Some(apply);
        }
    }
}

/// K tenant replicas of the full LSP layer-wise schedule over ONE set of
/// resources — the DES model of the runtime's multi-tenant arbiter: every
/// `t{k}.`-prefixed replica competes for the same GPU driver, d2h/h2d
/// links and CPU updater, exactly as the arbiter's tenants share one link
/// pair and one updater pool.  `tenants <= 1` falls through to the plain
/// lsp-layerwise builder (unprefixed task names), making the solo
/// degeneracy exact.
fn multi_tenant(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize) {
    let k = w.tenants.max(1);
    if k == 1 {
        layerwise(sim, c, w, iters, true);
        return;
    }
    for t in 0..k {
        layerwise_prefixed(sim, c, w, iters, true, &format!("t{t}."));
    }
}

/// Stall-free LSP schedule (`async-lsp`): per layer, the backward +
/// compress is followed by an immediate on-GPU apply of the important
/// slice; only the (1-rho)-scaled tail runs the offload -> CPU update ->
/// upload pipeline, and a forward gates on the tail apply from S+1
/// iterations back (bounded staleness) instead of the previous one.  Pure
/// FCFS priorities suffice — the LCFS transition exists to unblock the next
/// iteration's first fwd, which no longer waits on this iteration's tail.
fn layerwise_async(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize) {
    let n = w.n_layers;
    let q = (1.0 - w.async_rho.clamp(0.0, 1.0)).max(0.0);
    let s = w.async_staleness as usize;
    let (off_t, up_t, upd_t) =
        (q * c.offload_layer_sub, q * c.upload_layer_sub, q * c.upd_layer_cpu_sub);
    // gates[it][l] = the apply task fwd l of iteration it + s + 1 waits on.
    let mut gates: Vec<Vec<TaskId>> = Vec::with_capacity(iters);
    for it in 0..iters {
        let mut prev: Option<TaskId> = None;
        for l in 0..n {
            let mut deps: Vec<TaskId> = prev.into_iter().collect();
            if it > s {
                deps.push(gates[it - 1 - s][l]);
            }
            prev = Some(sim.add(format!("i{it}.fwd{l}"), Resource::Gpu, c.fwd_layer_gpu, &deps));
        }
        let mut bwd_prev = prev.unwrap();
        let mut iter_gates: Vec<Option<TaskId>> = vec![None; n];
        for l in (0..n).rev() {
            let bwd =
                sim.add(format!("i{it}.bwd{l}"), Resource::Gpu, c.bwd_layer_gpu, &[bwd_prev]);
            bwd_prev = bwd;
            let cmp =
                sim.add(format!("i{it}.cmp{l}"), Resource::Gpu, c.compress_layer_gpu, &[bwd]);
            // Important slice: synchronous on-GPU apply right away (absent
            // when rho = 0 — nothing to apply).
            let sync = if q < 1.0 {
                sim.add(format!("i{it}.sync{l}"), Resource::Gpu, c.apply_layer_gpu, &[cmp])
            } else {
                cmp
            };
            if q > 0.0 {
                let depth = (n - 1 - l) as i64;
                // The tail pipeline splits into sub-layer chunks via the
                // SAME helper as the synchronous layerwise schedule; the
                // staleness gate still waits on the whole layer's last
                // chunk.
                let cch = w.layer_chunks(true) as usize;
                // Same updater penalty as the synchronous builder: a real
                // split runs each chunk's Adam single-threaded.
                let upd_eff = if cch > 1 { upd_t * c.upd_chunk_penalty } else { upd_t };
                let ups =
                    chunked_layer_tail(sim, "", it, l, cmp, off_t, upd_eff, up_t, cch, depth);
                let apply = sim.add_prio(
                    format!("i{it}.apply{l}"),
                    Resource::Gpu,
                    c.apply_layer_gpu,
                    &ups,
                    1000 + l as i64,
                );
                iter_gates[l] = Some(apply);
            } else {
                // rho = 1: nothing ships; the sync apply is the gate.
                iter_gates[l] = Some(sync);
            }
        }
        gates.push(iter_gates.into_iter().map(|t| t.expect("every layer gated")).collect());
    }
}

/// Forward-only serving DAG: per decode iteration, every layer's weights
/// stream h2d (`i{it}.wload{l}`) and its forward runs on the GPU
/// (`i{it}.fwd{l}`).  Dependencies mirror the runtime engine's
/// recurrence over the global layer index `g = it * n + l`:
///
/// * stream `g` serializes on the link behind stream `g - 1` and may not
///   start before compute `g - depth` consumed its slot (the device
///   weight budget holds `prefetch_depth` layers);
/// * forward `g` waits on its own stream and the previous forward.
///
/// The runtime's KV restore charge has no DES task — the agreement test
/// runs the engine with a KV budget that never spills, which is also the
/// regime the closed form prices.
fn infer(sim: &mut Sim, c: &Costs, w: &Workload, iters: usize) {
    let n = w.n_layers;
    let depth = w.prefetch_depth.max(1);
    let mut computes: Vec<TaskId> = Vec::with_capacity(iters * n);
    let mut last_stream: Option<TaskId> = None;
    for it in 0..iters {
        for l in 0..n {
            let g = it * n + l;
            let mut sdeps: Vec<TaskId> = last_stream.into_iter().collect();
            if g >= depth {
                sdeps.push(computes[g - depth]);
            }
            let stream = sim.add(
                format!("i{it}.wload{l}"),
                Resource::H2D,
                c.upload_layer_full,
                &sdeps,
            );
            last_stream = Some(stream);
            let mut cdeps = vec![stream];
            cdeps.extend(computes.last().copied());
            computes.push(sim.add(
                format!("i{it}.fwd{l}"),
                Resource::Gpu,
                c.fwd_layer_gpu,
                &cdeps,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::PaperModel;

    fn setup() -> (HardwareProfile, Workload) {
        (
            HardwareProfile::workstation(),
            Workload::paper(PaperModel::Llama7B, 2048, 2048),
        )
    }

    #[test]
    fn all_schedules_run_and_validate() {
        let (hw, w) = setup();
        for kind in ScheduleKind::ALL {
            let rep = build_schedule(kind, &hw, &w, 3).unwrap();
            assert!(rep.iter_time > 0.0, "{kind:?}");
            assert!(rep.iter_time.is_finite());
        }
    }

    #[test]
    fn ordering_matches_paper() {
        // native <= lsp < zero-layerwise <= zero; swap is comm-bound worst.
        let (hw, w) = setup();
        let t = |k| build_schedule(k, &hw, &w, 3).unwrap().iter_time;
        let native = t(ScheduleKind::Native);
        let lsp = t(ScheduleKind::LspLayerwise);
        let zero = t(ScheduleKind::Zero);
        let zero_lw = t(ScheduleKind::ZeroLayerwise);
        let swap = t(ScheduleKind::SwapOnly);
        // LSP is near-native; in the idealized DES it can even edge out
        // native because the full on-GPU Adam (0.11 s) is replaced by a
        // ~4 ms compress (the paper's real runs show +10-17%).
        assert!(lsp >= native * 0.85, "native {native} lsp {lsp}");
        assert!(lsp <= native * 1.4, "LSP should be near-native: {lsp} vs {native}");
        assert!(lsp < zero, "lsp {lsp} zero {zero}");
        assert!(zero_lw <= zero * 1.001, "zero_lw {zero_lw} zero {zero}");
        assert!(swap > zero, "swap {swap} should be worst, zero {zero}");
    }

    #[test]
    fn lsp_near_native_on_workstation() {
        // Paper Fig. 6: LSP incurs ~10-17% slowdown over native.
        let (hw, w) = setup();
        let native = build_schedule(ScheduleKind::Native, &hw, &w, 3).unwrap().iter_time;
        let lsp = build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 3).unwrap().iter_time;
        let slowdown = lsp / native;
        assert!(slowdown < 1.4, "LSP slowdown vs native: {slowdown}");
    }

    #[test]
    fn zero_slowdown_matches_eq1() {
        let (hw, w) = setup();
        let c = super::super::cost_model::Costs::derive(&hw, &w);
        let des = build_schedule(ScheduleKind::Zero, &hw, &w, 4).unwrap().iter_time;
        let eq1 = super::super::cost_model::eq1_zero_iter(&c, w.n_layers);
        let rel = (des - eq1).abs() / eq1;
        assert!(rel < 0.15, "DES {des} vs Eq.1 {eq1} ({rel})");
    }

    #[test]
    fn lsp_within_eq4_envelope() {
        let (hw, w) = setup();
        let c = super::super::cost_model::Costs::derive(&hw, &w);
        let des = build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 4).unwrap().iter_time;
        let eq4 = super::super::cost_model::eq4_lsp_iter(&c, w.n_layers);
        // DES must not beat the analytic lower bound, and should be close.
        assert!(des >= eq4 * 0.95, "DES {des} below Eq.4 {eq4}");
        assert!(des <= eq4 * 1.35, "DES {des} far above Eq.4 {eq4}");
    }

    #[test]
    fn async_lsp_never_slower_than_lsp_and_staleness_helps() {
        let (hw, w) = setup();
        let lsp = build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 4).unwrap().iter_time;
        let asn = build_schedule(ScheduleKind::AsyncLsp, &hw, &w, 4).unwrap().iter_time;
        assert!(asn <= lsp * 1.05, "async {asn} vs lsp {lsp}");

        let mut w0 = w.clone();
        w0.async_staleness = 0;
        let t0 = build_schedule(ScheduleKind::AsyncLsp, &hw, &w0, 4).unwrap().iter_time;
        let mut w4 = w.clone();
        w4.async_staleness = 4;
        let t4 = build_schedule(ScheduleKind::AsyncLsp, &hw, &w4, 4).unwrap().iter_time;
        assert!(t4 <= t0 * 1.02, "staleness 4 {t4} vs staleness 0 {t0}");

        // The rho corners validate, and all-sync (ships nothing) never
        // loses to the default split (same sync work, no tail pipeline).
        let mut w_sync = w.clone();
        w_sync.async_rho = 1.0;
        let ts = build_schedule(ScheduleKind::AsyncLsp, &hw, &w_sync, 4).unwrap().iter_time;
        assert!(ts <= asn * 1.02, "all-sync {ts} vs default async {asn}");
        let mut w_async = w.clone();
        w_async.async_rho = 0.0;
        let ta = build_schedule(ScheduleKind::AsyncLsp, &hw, &w_async, 4).unwrap().iter_time;
        assert!(ta.is_finite() && ta > 0.0);
    }

    /// Sub-layer chunking (the PIPO follow-up): every chunked schedule
    /// validates, is never slower than its whole-layer counterpart, and
    /// Zero — whose whole-buffer CPU Adam serializes behind the full
    /// offload — gets a strict improvement from per-chunk updates.  This
    /// is the DES side of the acceptance criterion: the simulator predicts
    /// the same direction the virtual-clock runtime measures.
    #[test]
    fn chunked_schedules_never_slower_and_zero_strictly_improves() {
        let (mut hw, w) = setup();
        // Pin the pure pipelining effect: with no thread-level Adam speedup
        // to forfeit (`cpu_adam_parallelism = 1`), sub-threshold chunks pay
        // no updater penalty and chunking can only overlap work.  The
        // penalty direction under real hardware is pinned separately by
        // `sub_threshold_chunks_slow_zero_on_real_hw`.
        hw.cpu_adam_parallelism = 1.0;
        let run = |k: ScheduleKind, chunk: usize| {
            let mut wc = w.clone();
            wc.link_chunk_elems = chunk;
            let sim = build_sim(k, &hw, &wc, 4);
            let sched = sim.run().unwrap();
            crate::sim::engine::validate(sim.tasks(), &sched).unwrap();
            build_schedule(k, &hw, &wc, 4).unwrap().iter_time
        };
        for kind in [ScheduleKind::LspLayerwise, ScheduleKind::AsyncLsp, ScheduleKind::Zero] {
            let whole = run(kind, 0);
            for chunk in [4096usize, 65536] {
                let chunked = run(kind, chunk);
                assert!(
                    chunked <= whole * 1.01,
                    "{kind:?} chunk {chunk}: {chunked} vs whole {whole}"
                );
            }
        }
        let z_whole = run(ScheduleKind::Zero, 0);
        let z_chunk = run(ScheduleKind::Zero, 65536);
        assert!(
            z_chunk < z_whole * 0.99,
            "chunked zero {z_chunk} must strictly beat whole-layer {z_whole}"
        );
        // A budget so large that nothing splits (cch == 1 for every layer)
        // must reproduce the whole-layer DES exactly — the simulator-side
        // n_chunks = 1 degeneracy (llama-7B layers are ~2.2e8 params,
        // within one 16 Mi-elem chunk only for the subspace path, so pin
        // the lsp builder where payloads are d^2 = 4 Mi elems).
        let l_whole = run(ScheduleKind::LspLayerwise, 0);
        let l_one = run(ScheduleKind::LspLayerwise, 16_777_216);
        assert_eq!(l_one.to_bits(), l_whole.to_bits(), "cch == 1 must be the unchunked DES");
    }

    /// DES side of the chunked-updater cost fix: under the *real*
    /// workstation profile (threaded Adam ~4x a single core), a 4096-elem
    /// chunk budget drops every chunk below `optim::PAR_ADAM_MIN_LEN`, so
    /// the updater runs single-threaded and Zero's chunked schedule gets
    /// slower than the same schedule at an at-threshold budget — the
    /// direction the virtual-clock runtime measures.  At-threshold chunks
    /// (65536) keep the parallel rate and still beat the whole-layer
    /// schedule.
    #[test]
    fn sub_threshold_chunks_slow_zero_on_real_hw() {
        let (hw, w) = setup();
        assert!(hw.cpu_adam_parallelism > 1.0, "test needs a real threaded speedup");
        let run = |chunk: usize| {
            let mut wc = w.clone();
            wc.link_chunk_elems = chunk;
            build_schedule(ScheduleKind::Zero, &hw, &wc, 4).unwrap().iter_time
        };
        let whole = run(0);
        let at_threshold = run(crate::optim::PAR_ADAM_MIN_LEN);
        let sub_threshold = run(4096);
        assert!(
            at_threshold <= whole * 1.01,
            "at-threshold chunking must not regress: {at_threshold} vs {whole}"
        );
        assert!(
            sub_threshold > at_threshold * 1.05,
            "sub-threshold chunks must pay the single-thread Adam penalty: \
             {sub_threshold} vs {at_threshold}"
        );
    }

    #[test]
    fn multi_tenant_degenerates_to_solo_and_scales_with_contention() {
        let (hw, w) = setup();
        // tenants = 1: bit-for-bit the lsp-layerwise DES (same task list,
        // same makespan).
        let solo = build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 3).unwrap().iter_time;
        let one = build_schedule(ScheduleKind::MultiTenant, &hw, &w, 3).unwrap().iter_time;
        assert_eq!(one.to_bits(), solo.to_bits(), "tenants = 1 must be the solo schedule");
        let s1 = build_sim(ScheduleKind::MultiTenant, &hw, &w, 2);
        let s0 = build_sim(ScheduleKind::LspLayerwise, &hw, &w, 2);
        assert_eq!(s1.tasks().len(), s0.tasks().len());

        // K = 4 equal tenants: the DAG validates, carries 4x the tasks
        // under t{k}. prefixes, and the shared resources make the run at
        // least as long as solo but no worse than fully serialized.
        let mut w4 = w.clone();
        w4.tenants = 4;
        let sim = build_sim(ScheduleKind::MultiTenant, &hw, &w4, 2);
        assert_eq!(sim.tasks().len(), 4 * s0.tasks().len());
        assert!(sim.tasks().iter().any(|t| t.name.starts_with("t0.i0.fwd")));
        assert!(sim.tasks().iter().any(|t| t.name.starts_with("t3.i0.apply")));
        let sched = sim.run().unwrap();
        crate::sim::engine::validate(sim.tasks(), &sched).unwrap();
        let four = build_schedule(ScheduleKind::MultiTenant, &hw, &w4, 3).unwrap().iter_time;
        assert!(four >= solo * 0.99, "4 tenants can't beat one: {four} vs {solo}");
        assert!(four <= solo * 4.0 * 1.01, "sharing can't be worse than serial: {four}");
    }

    /// Serving DES: depth-1 reproduces the serial closed form exactly,
    /// depth-2 overlaps stream and compute (>= 20% faster on the paper
    /// workload, where the two costs are same-order), and steady state
    /// saturates at depth 2 — the structure `eq_infer_iter` encodes and
    /// the runtime agreement test (`tests/infer.rs`) measures.
    #[test]
    fn infer_schedule_overlap_and_closed_form_degeneracy() {
        let (hw, w) = setup();
        let c = super::super::cost_model::Costs::derive(&hw, &w);
        let run = |depth: usize| {
            let mut wd = w.clone();
            wd.prefetch_depth = depth;
            let sim = build_sim(ScheduleKind::Infer, &hw, &wd, 4);
            let sched = sim.run().unwrap();
            crate::sim::engine::validate(sim.tasks(), &sched).unwrap();
            build_schedule(ScheduleKind::Infer, &hw, &wd, 4).unwrap().iter_time
        };
        let d1 = run(1);
        let d2 = run(2);
        let d4 = run(4);
        let eq1 = super::super::cost_model::eq_infer_iter(&c, w.n_layers, 1);
        let rel1 = (d1 - eq1).abs() / eq1;
        assert!(rel1 < 1e-9, "depth-1 DES {d1} must be the serial closed form {eq1} ({rel1})");
        let eq2 = super::super::cost_model::eq_infer_iter(&c, w.n_layers, 2);
        let rel2 = (d2 - eq2).abs() / eq2;
        assert!(rel2 < 1e-6, "depth-2 DES {d2} vs closed form {eq2} ({rel2})");
        assert!(d2 <= d1 * 0.8, "prefetch must cut >= 20%: depth2 {d2} vs depth1 {d1}");
        let sat = (d4 - d2).abs() / d2;
        assert!(sat < 1e-6, "steady state saturates at depth 2: {d4} vs {d2}");
    }

    #[test]
    fn delayed_update_improves_zero_throughput() {
        let (hw, w) = setup();
        let zero = build_schedule(ScheduleKind::Zero, &hw, &w, 4).unwrap().iter_time;
        let delayed = build_schedule(ScheduleKind::ZeroDelayed, &hw, &w, 4).unwrap().iter_time;
        assert!(delayed < zero * 1.05, "delayed {delayed} vs zero {zero}");
    }

    #[test]
    fn laptop_slowdowns_in_fig2_band() {
        // Fig. 2: Zero slows training 1.93x-4.28x across configs.
        let hw = HardwareProfile::laptop();
        let w = Workload::paper(PaperModel::Gpt2_1_3B, 512, 1024);
        let rep = build_schedule(ScheduleKind::Zero, &hw, &w, 3).unwrap();
        let slow = rep.iter_time / rep.gpu_compute;
        assert!((1.5..5.5).contains(&slow), "laptop zero slowdown {slow}");
    }
}
