//! Discrete-event simulator of single-GPU offloaded training.
//!
//! The paper's testbeds (RTX 4090 + Threadripper, A1000 laptop) are not
//! available here (repro band 0/5), so the schedule-level claims — Fig. 2's
//! slowdown breakdown, Fig. 3's pipelines, Fig. 6's throughput ablation,
//! Fig. 7a's per-iteration breakdown, and the Eq. 1 / Eq. 4 critical paths —
//! are reproduced on a calibrated discrete-event model with four
//! single-server resources: the GPU stream, the CPU update pool, and the two
//! directions of the PCIe link (full duplex = independent servers).
//!
//! Costs come from `cost_model` (calibrated against the paper's own
//! narrative numbers: 14 GB / 15 GB/s ≈ 0.93 s gradient offload, 1.92 s
//! fused CPU Adam over 7 B params, ...); the simulator itself is exact
//! list-scheduling over the task DAGs that `schedules` builds.

pub mod cost_model;
pub mod engine;
pub mod report;
pub mod schedules;

pub use cost_model::{HardwareProfile, Workload};
pub use engine::{Resource, Sim, TaskId, TaskSpec};
pub use report::{Breakdown, IterReport};
pub use schedules::{build_schedule, ScheduleKind};
