//! The Motivation-section numerical analyses, printable as the paper's
//! tables: Table 1 / Table 5 (configs + timings), Table 2 (memory & rank per
//! method), the Observation lower bound, and the Eq. 1 vs Eq. 4 critical
//! paths.  Every function returns structured rows so benches and tests can
//! assert on them; `print_*` renders the paper-style table.

pub mod bias_study;

use crate::model::memory::{
    galore_footprint, lora_footprint, lsp_footprint, min_comm_per_iter, MemoryBreakdown,
    PaperModel,
};
use crate::sim::cost_model::{eq1_zero_iter, eq4_lsp_iter, Costs, HardwareProfile, Workload};
use crate::util::{human_bytes, human_secs};

/// One row of Table 1 / Table 5.
#[derive(Debug, Clone)]
pub struct ConfigTable {
    pub model: PaperModel,
    pub hw: HardwareProfile,
    pub mem: MemoryBreakdown,
    pub costs: Costs,
    pub n_layers: usize,
}

impl ConfigTable {
    pub fn build(model: PaperModel, hw: HardwareProfile, tokens: u64) -> ConfigTable {
        let w = Workload::paper(model, tokens, (model.hidden() / 2) as usize);
        let act = match model {
            PaperModel::Llama7B => 8u64 << 30,
            PaperModel::Gpt2_1_3B => 500 << 20,
            _ => 2 << 30,
        };
        ConfigTable {
            model,
            hw: hw.clone(),
            mem: MemoryBreakdown::fp16_adam(model.params(), act),
            costs: Costs::derive(&hw, &w),
            n_layers: w.n_layers,
        }
    }

    pub fn print(&self) {
        let c = &self.costs;
        let n = self.n_layers as f64;
        println!("Table: {} on {} (fp16)", self.model.name(), self.hw.name);
        println!(
            "| Parameters | Optimizer State | Activations | CPU-GPU BW | #Layers | GPU Memory |"
        );
        println!(
            "| {} | {} | {} | ~{:.0} GB/s | {} | {} |",
            human_bytes(self.mem.params),
            human_bytes(self.mem.optimizer),
            human_bytes(self.mem.activations),
            self.hw.h2d_bytes_per_s / 1e9,
            self.n_layers,
            human_bytes(self.hw.gpu_mem_bytes),
        );
        println!("| FWD on CPU | BWD on CPU | UPD on CPU | FWD on GPU | BWD on GPU | UPD on GPU |");
        println!(
            "| {} | {} | {} | {} | {} | {} |",
            human_secs(c.fwd_layer_cpu * n),
            human_secs(c.bwd_layer_cpu * n),
            human_secs(c.upd_layer_cpu_full * n),
            human_secs(c.fwd_layer_gpu * n),
            human_secs(c.bwd_layer_gpu * n),
            human_secs(c.upd_layer_gpu_native * n),
        );
        let total = self.mem.total();
        let lower = min_comm_per_iter(total, self.hw.gpu_mem_bytes);
        println!(
            "Observation: M_tot={} M_gpu={} -> >= {} communicated per iteration \
             ({} at swap bandwidth)",
            human_bytes(total),
            human_bytes(self.hw.gpu_mem_bytes),
            human_bytes(lower),
            human_secs(lower as f64 / self.hw.swap_bytes_per_s),
        );
    }
}

/// One row of Table 2.
#[derive(Debug, Clone)]
pub struct MethodRow {
    pub method: &'static str,
    pub gpu_extra_bytes: u64,
    pub opt_space_rank: u64,
}

/// Table 2 for a single weight matrix `m x n`.
pub fn table2(m: u64, n: u64, rank: u64, d: u64, r: u64, tau: u64) -> Vec<MethodRow> {
    let beta = 3; // Adam
    let lora = lora_footprint(m, n, rank, beta, 2);
    let galore = galore_footprint(m, n, rank, beta, tau, 1.0, 2);
    let lsp = lsp_footprint(m, n, d, r, tau, 1.0, 2);
    vec![
        MethodRow {
            method: "LoRA",
            gpu_extra_bytes: lora.gpu_extra_bytes,
            opt_space_rank: lora.opt_space_rank,
        },
        MethodRow {
            method: "GaLore",
            gpu_extra_bytes: galore.gpu_extra_bytes,
            opt_space_rank: galore.opt_space_rank,
        },
        MethodRow {
            method: "LSP-Offload",
            gpu_extra_bytes: lsp.gpu_extra_bytes,
            opt_space_rank: lsp.opt_space_rank,
        },
    ]
}

pub fn print_table2(m: u64, n: u64, rank: u64, d: u64, r: u64, tau: u64) {
    println!("Table 2: W in R^{{{m}x{n}}}, rank={rank}, (d,r)=({d},{r}), tau={tau}");
    println!("| Method      | extra GPU memory | rank(optim space) |");
    for row in table2(m, n, rank, d, r, tau) {
        println!(
            "| {:11} | {:>16} | {:>17} |",
            row.method,
            human_bytes(row.gpu_extra_bytes),
            row.opt_space_rank
        );
    }
}

/// Eq. 1 vs Eq. 4 closed-form comparison for a workload.
#[derive(Debug, Clone)]
pub struct CriticalPaths {
    pub gpu_compute: f64,
    pub eq1_zero: f64,
    pub eq4_lsp: f64,
}

pub fn critical_paths(hw: &HardwareProfile, w: &Workload) -> CriticalPaths {
    let c = Costs::derive(hw, w);
    CriticalPaths {
        gpu_compute: c.gpu_compute(w.n_layers),
        eq1_zero: eq1_zero_iter(&c, w.n_layers),
        eq4_lsp: eq4_lsp_iter(&c, w.n_layers),
    }
}

pub fn print_critical_paths(hw: &HardwareProfile, w: &Workload) {
    let cp = critical_paths(hw, w);
    println!(
        "critical paths [{} / {}]: GPU compute {} | Eq.1 (Zero) {} ({:.2}x) | \
         Eq.4 (LSP) {} ({:.2}x)",
        w.name,
        hw.name,
        human_secs(cp.gpu_compute),
        human_secs(cp.eq1_zero),
        cp.eq1_zero / cp.gpu_compute,
        human_secs(cp.eq4_lsp),
        cp.eq4_lsp / cp.gpu_compute,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_rows_reflect_the_papers_claims() {
        // Paper example: hidden 2048, rank 512 vs LSP (d=1024, r=4).
        let rows = table2(2048, 2048, 512, 1024, 4, 1);
        let lora = &rows[0];
        let galore = &rows[1];
        let lsp = &rows[2];
        // LSP uses far less GPU memory than both.
        assert!(lsp.gpu_extra_bytes * 10 < lora.gpu_extra_bytes);
        assert!(lsp.gpu_extra_bytes * 10 < galore.gpu_extra_bytes);
        // And reaches a higher-rank optimization space than LoRA.
        assert!(lsp.opt_space_rank > lora.opt_space_rank);
    }

    #[test]
    fn table2_lsp_rank_grows_with_tau() {
        let t1 = table2(2048, 2048, 512, 1024, 4, 1)[2].opt_space_rank;
        let t2 = table2(2048, 2048, 512, 1024, 4, 2)[2].opt_space_rank;
        assert!(t2 >= t1);
        // Capped by min(m, n).
        let tmax = table2(2048, 2048, 512, 1024, 4, 100)[2].opt_space_rank;
        assert_eq!(tmax, 2048);
    }

    #[test]
    fn config_tables_build_for_both_testbeds() {
        let t1 = ConfigTable::build(PaperModel::Llama7B, HardwareProfile::workstation(), 2048);
        assert_eq!(t1.mem.params, 14_000_000_000);
        let t5 = ConfigTable::build(PaperModel::Gpt2_1_3B, HardwareProfile::laptop(), 512);
        assert_eq!(t5.mem.params, 2_600_000_000);
        t1.print();
        t5.print();
    }

    #[test]
    fn eq1_vs_eq4_gap() {
        let hw = HardwareProfile::workstation();
        let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        let cp = critical_paths(&hw, &w);
        assert!(cp.eq4_lsp < cp.eq1_zero);
        // The paper's 33-62% time reduction band at equal accuracy comes from
        // per-iteration speedups of roughly this scale.
        let speedup = cp.eq1_zero / cp.eq4_lsp;
        assert!((1.5..4.0).contains(&speedup), "speedup {speedup}");
    }
}
