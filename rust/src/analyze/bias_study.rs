//! Estimation-bias study (Figs 7b / 9): learned (d, r)-sparse projectors vs
//! random sparse projectors vs GaLore's SVD projector, evaluated on *real*
//! model gradients, separately on the calibration gradient (train error)
//! and on held-out gradients (generalization).
//!
//! Gradients come from the monolithic `train_step` artifact: we run a short
//! native fine-tune on the synthetic corpus and collect layer-0 gradients
//! for every LSP kind, split into calibration / validation.

use anyhow::Result;
use xla::Literal;

use crate::data::{Batcher, Corpus};
use crate::linalg::randomized_svd;
use crate::model::ParamStore;
use crate::optim::AdamState;
use crate::runtime::Engine;
use crate::sparse::ProjectorPair;
use crate::tensor::ops::{matmul, matmul_tn, sub};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct BiasRow {
    pub kind: String,
    pub method: String,
    pub d: usize,
    pub r: usize,
    pub calib_bias: f32,
    pub val_bias: f32,
}

#[derive(Debug)]
pub struct BiasReport {
    pub rows: Vec<BiasRow>,
}

impl BiasReport {
    pub fn print(&self) {
        println!("estimation bias (relative ||PP^T G QQ^T - G||_F / ||G||_F):");
        println!(
            "| {:8} | {:22} | {:>4} | {:>3} | {:>11} | {:>9} |",
            "kind", "method", "d", "r", "calib bias", "val bias"
        );
        for r in &self.rows {
            println!(
                "| {:8} | {:22} | {:>4} | {:>3} | {:>11.4} | {:>9.4} |",
                r.kind, r.method, r.d, r.r, r.calib_bias, r.val_bias
            );
        }
    }
}

/// Collect per-kind layer-0 gradients from `steps` native training steps.
fn collect_grads(
    eng: &Engine,
    steps: usize,
    seed: u64,
) -> Result<Vec<(String, Vec<Tensor>)>> {
    let man = eng.man.clone();
    let c = &man.config;
    let mut params = ParamStore::init(&man, seed)?;
    let corpus = Corpus::synthetic(c.vocab, (c.batch * c.seq + 1) * (steps + 2) * 4, seed ^ 0x5);
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq, seed);
    let mono = eng.exec("train_step")?;
    let mut states: Vec<AdamState> =
        params.tensors.iter().map(|t| AdamState::new(t.len())).collect();

    let mut per_kind: Vec<(String, Vec<Tensor>)> =
        man.kinds.keys().map(|k| (k.clone(), Vec::new())).collect();
    for _ in 0..steps {
        let b = batcher.next_batch();
        let mut args: Vec<Literal> = vec![
            eng.lit_i32(&[c.batch, c.seq], &b.tokens)?,
            eng.lit_i32(&[c.batch, c.seq], &b.targets)?,
        ];
        for t in &params.tensors {
            args.push(eng.lit_tensor(t)?);
        }
        let outs = mono.call(&args)?;
        // outs: loss, then grads aligned with params order.
        for (ki, (kind, grads)) in per_kind.iter_mut().enumerate() {
            let meta = &man.kinds[kind];
            let pidx = 2 + meta.param_index; // layer 0 block starts at 2
            let g = eng.to_tensor(&outs[1 + pidx], &[meta.m, meta.n])?;
            grads.push(g);
            let _ = ki;
        }
        // Native Adam update so later gradients are from evolving weights.
        for (i, t) in params.tensors.iter_mut().enumerate() {
            let g: Vec<f32> = eng.to_vec_f32(&outs[1 + i])?;
            let delta = states[i].step_vec(&g);
            for (wv, dv) in t.data_mut().iter_mut().zip(&delta) {
                *wv -= 1e-3 * dv;
            }
        }
    }
    Ok(per_kind)
}

fn mean_grad(grads: &[Tensor]) -> Tensor {
    let mut acc = Tensor::zeros(grads[0].shape());
    for g in grads {
        crate::tensor::ops::axpy(&mut acc, 1.0 / grads.len() as f32, g);
    }
    acc
}

fn pair_bias_on(pair: &ProjectorPair, grads: &[Tensor]) -> f32 {
    let mut acc = 0.0;
    for g in grads {
        acc += pair.bias(g).unwrap().0;
    }
    acc / grads.len() as f32
}

/// One-sided GaLore bias: `||P P^T G - G||_F / ||G||_F` with P = top-rank
/// left singular vectors of the calibration gradient.
fn galore_bias(p: &Tensor, grads: &[Tensor]) -> Result<f32> {
    let mut acc = 0.0;
    for g in grads {
        let proj = matmul(p, &matmul_tn(p, g)?)?;
        acc += sub(&proj, g).frob_norm() / g.frob_norm().max(1e-30);
    }
    Ok(acc / grads.len() as f32)
}

/// Learn projector values on `calib` with the `learn_<kind>` artifact.
fn learn_pair(
    eng: &Engine,
    entry: &str,
    pair: &mut ProjectorPair,
    calib: &Tensor,
    budget: u32,
    lr: f32,
) -> Result<()> {
    let (m, n, r) = (pair.p.rows, pair.q.rows, pair.p.r);
    let e = eng.exec(entry)?;
    let mut p_val = pair.p.val.clone();
    let mut q_val = pair.q.val.clone();
    let mut mp = vec![0f32; p_val.len()];
    let mut vp = vec![0f32; p_val.len()];
    let mut mq = vec![0f32; q_val.len()];
    let mut vq = vec![0f32; q_val.len()];
    for t in 1..=budget {
        let out = e.call(&[
            eng.lit_tensor(calib)?,
            eng.lit_i32(&[m, r], &pair.p.idx)?,
            eng.lit_f32(&[m, r], &p_val)?,
            eng.lit_i32(&[n, r], &pair.q.idx)?,
            eng.lit_f32(&[n, r], &q_val)?,
            eng.lit_f32(&[m, r], &mp)?,
            eng.lit_f32(&[m, r], &vp)?,
            eng.lit_f32(&[n, r], &mq)?,
            eng.lit_f32(&[n, r], &vq)?,
            eng.lit_scalar(t as f32)?,
            eng.lit_scalar(lr)?,
        ])?;
        p_val = eng.to_vec_f32(&out[0])?;
        q_val = eng.to_vec_f32(&out[1])?;
        mp = eng.to_vec_f32(&out[2])?;
        vp = eng.to_vec_f32(&out[3])?;
        mq = eng.to_vec_f32(&out[4])?;
        vq = eng.to_vec_f32(&out[5])?;
    }
    pair.p.val = p_val;
    pair.q.val = q_val;
    Ok(())
}

pub fn run(eng: &Engine, n_calib: usize, n_val: usize, seed: u64) -> Result<BiasReport> {
    let man = eng.man.clone();
    let per_kind = collect_grads(eng, n_calib + n_val, seed)?;
    let mut rng = Rng::new(seed ^ 0x1ce);
    let mut rows = Vec::new();

    for (kind, grads) in &per_kind {
        let meta = &man.kinds[kind];
        let (calib_set, val_set) = grads.split_at(n_calib);
        let calib = mean_grad(calib_set);

        // Random sparse projector (JL init, unlearned).
        let random_pair = ProjectorPair::init(meta.m, meta.n, meta.d, meta.r, &mut rng);
        rows.push(BiasRow {
            kind: kind.clone(),
            method: "sparse-random".into(),
            d: meta.d,
            r: meta.r,
            calib_bias: random_pair.bias(&calib).unwrap().0,
            val_bias: pair_bias_on(&random_pair, val_set),
        });

        // Learned sparse projector (Eq. 3 on the calibration gradient).
        let mut learned = random_pair.clone();
        learn_pair(eng, &format!("learn_{kind}"), &mut learned, &calib, 120, 0.02)?;
        rows.push(BiasRow {
            kind: kind.clone(),
            method: "sparse-learned".into(),
            d: meta.d,
            r: meta.r,
            calib_bias: learned.bias(&calib).unwrap().0,
            val_bias: pair_bias_on(&learned, val_set),
        });

        // GaLore SVD projectors at a few (distinct) ranks.
        let mut ranks: Vec<usize> = [meta.r, 4 * meta.r, meta.d / 2]
            .into_iter()
            .map(|r| r.max(1).min(meta.m.min(meta.n)))
            .collect();
        ranks.sort_unstable();
        ranks.dedup();
        for rank in ranks {
            let svd = randomized_svd(&calib, rank, 2, &mut rng)?;
            rows.push(BiasRow {
                kind: kind.clone(),
                method: format!("galore-svd(rank={rank})"),
                d: rank,
                r: rank,
                calib_bias: galore_bias(&svd.u, std::slice::from_ref(&calib))?,
                val_bias: galore_bias(&svd.u, val_set)?,
            });
        }
    }

    // d-sweep with learned projectors, if the artifacts carry sweep entries.
    for (name, _) in man.entries.iter() {
        if let Some(rest) = name.strip_prefix("learn_sweep_") {
            // learn_sweep_<kind>_d<d>
            let Some((kind, dstr)) = rest.rsplit_once("_d") else { continue };
            let Ok(d) = dstr.parse::<usize>() else { continue };
            let meta = &man.kinds[kind];
            let grads = &per_kind.iter().find(|(k, _)| k == kind).unwrap().1;
            let (calib_set, val_set) = grads.split_at(n_calib);
            let calib = mean_grad(calib_set);
            let mut pair = ProjectorPair::init(meta.m, meta.n, d, meta.r, &mut rng);
            learn_pair(eng, name, &mut pair, &calib, 120, 0.02)?;
            rows.push(BiasRow {
                kind: kind.to_string(),
                method: "sparse-learned-sweep".into(),
                d,
                r: meta.r,
                calib_bias: pair.bias(&calib).unwrap().0,
                val_bias: pair_bias_on(&pair, val_set),
            });
        }
    }

    Ok(BiasReport { rows })
}
