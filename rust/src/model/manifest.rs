//! Parse `artifacts/<preset>/manifest.json`.
//!
//! The manifest is the contract between the build-time python compiler and
//! the runtime rust coordinator: model dimensions, per-kind LSP subspace
//! metadata, the canonical block-parameter list, and for every HLO entry the
//! argument order / dtypes / shapes plus whether its root is a tuple.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub n_layer: usize,
    pub seq: usize,
    pub batch: usize,
    pub r: usize,
    pub d_frac: f64,
    pub n_params: usize,
}

/// Per weight-kind LSP metadata ("qkv", "attn_o", "fc", "proj").
#[derive(Debug, Clone, PartialEq)]
pub struct KindMeta {
    pub m: usize,
    pub n: usize,
    pub d: usize,
    pub r: usize,
    pub lp: usize,
    pub lq: usize,
    /// Index into the canonical 12-entry block parameter list.
    pub param_index: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub tuple_out: bool,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<ArgSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    pub config: ModelCfg,
    pub kinds: BTreeMap<String, KindMeta>,
    /// Canonical per-block parameter (name, shape) list, in artifact order.
    pub block_params: Vec<(String, Vec<usize>)>,
    pub axpy_lens: Vec<usize>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let c = j.at(&["config"])?;
        let config = ModelCfg {
            vocab: c.at(&["vocab"])?.as_usize()?,
            d_model: c.at(&["d_model"])?.as_usize()?,
            n_head: c.at(&["n_head"])?.as_usize()?,
            d_ff: c.at(&["d_ff"])?.as_usize()?,
            n_layer: c.at(&["n_layer"])?.as_usize()?,
            seq: c.at(&["seq"])?.as_usize()?,
            batch: c.at(&["batch"])?.as_usize()?,
            r: c.at(&["r"])?.as_usize()?,
            d_frac: c.at(&["d_frac"])?.as_f64()?,
            n_params: c.at(&["n_params"])?.as_usize()?,
        };

        let mut kinds = BTreeMap::new();
        for (k, v) in j.at(&["kinds"])?.as_obj()? {
            kinds.insert(
                k.clone(),
                KindMeta {
                    m: v.at(&["m"])?.as_usize()?,
                    n: v.at(&["n"])?.as_usize()?,
                    d: v.at(&["d"])?.as_usize()?,
                    r: v.at(&["r"])?.as_usize()?,
                    lp: v.at(&["lp"])?.as_usize()?,
                    lq: v.at(&["lq"])?.as_usize()?,
                    param_index: v.at(&["param_index"])?.as_usize()?,
                },
            );
        }

        let mut block_params = Vec::new();
        for bp in j.at(&["block_params"])?.as_arr()? {
            block_params.push((
                bp.at(&["name"])?.as_str()?.to_string(),
                bp.at(&["shape"])?.usize_vec()?,
            ));
        }

        let axpy_lens = j.at(&["axpy_lens"])?.usize_vec()?;

        let parse_specs = |arr: &Json| -> Result<Vec<ArgSpec>> {
            arr.as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a
                            .get("name")
                            .map(|n| n.as_str().map(str::to_string))
                            .transpose()?
                            .unwrap_or_default(),
                        dtype: DType::parse(a.at(&["dtype"])?.as_str()?)?,
                        shape: a.at(&["shape"])?.usize_vec()?,
                    })
                })
                .collect()
        };

        let mut entries = BTreeMap::new();
        for e in j.at(&["entries"])?.as_arr()? {
            let name = e.at(&["name"])?.as_str()?.to_string();
            entries.insert(
                name.clone(),
                EntrySpec {
                    name,
                    file: dir.join(e.at(&["file"])?.as_str()?),
                    tuple_out: e.at(&["tuple_out"])?.as_bool()?,
                    args: parse_specs(e.at(&["args"])?)?,
                    outs: parse_specs(e.at(&["outs"])?)?,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            preset: j.at(&["preset"])?.as_str()?.to_string(),
            config,
            kinds,
            block_params,
            axpy_lens,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("manifest has no entry {name:?} (preset {})", self.preset))
    }

    /// Kind name for a block-parameter index, if that parameter is LSP'd.
    pub fn kind_for_param(&self, param_index: usize) -> Option<(&str, &KindMeta)> {
        self.kinds
            .iter()
            .find(|(_, m)| m.param_index == param_index)
            .map(|(k, m)| (k.as_str(), m))
    }
}

/// Locate an artifacts directory: explicit path, else `$LSP_ARTIFACTS`,
/// else `artifacts/<preset>` relative to the workspace.
pub fn find_artifacts(explicit: Option<&str>, preset: &str) -> Result<PathBuf> {
    if let Some(p) = explicit {
        return Ok(PathBuf::from(p));
    }
    if let Ok(p) = std::env::var("LSP_ARTIFACTS") {
        return Ok(PathBuf::from(p));
    }
    for base in ["artifacts", "../artifacts"] {
        let p = Path::new(base).join(preset);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
    }
    bail!(
        "no artifacts found for preset {preset:?}; run `make artifacts` \
         or set LSP_ARTIFACTS"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal manifest with the same schema aot.py emits.
    pub(crate) const SAMPLE: &str = r#"{
      "preset": "tiny",
      "config": {"vocab": 64, "d_model": 32, "n_head": 2, "d_ff": 64,
                 "n_layer": 2, "seq": 16, "batch": 2, "r": 2, "d_frac": 0.5,
                 "n_params": 19712},
      "kinds": {"qkv": {"m": 32, "n": 96, "d": 16, "r": 2, "lp": 4, "lq": 12,
                        "param_index": 2}},
      "block_params": [{"name": "ln1_g", "shape": [32]},
                       {"name": "w_qkv", "shape": [32, 96]}],
      "axpy_lens": [32, 3072],
      "entries": [
        {"name": "block_fwd", "file": "block_fwd.hlo.txt", "tuple_out": false,
         "args": [{"name": "h", "dtype": "f32", "shape": [2, 16, 32]}],
         "outs": [{"dtype": "f32", "shape": [2, 16, 32]}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("lsp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.config.d_model, 32);
        assert_eq!(m.config.n_params, 19712);
        let k = &m.kinds["qkv"];
        assert_eq!((k.m, k.n, k.d, k.r), (32, 96, 16, 2));
        assert_eq!(m.kind_for_param(2).unwrap().0, "qkv");
        assert!(m.kind_for_param(3).is_none());
        let e = m.entry("block_fwd").unwrap();
        assert!(!e.tuple_out);
        assert_eq!(e.args[0].shape, vec![2, 16, 32]);
        assert_eq!(e.args[0].elems(), 1024);
        assert!(m.entry("nope").is_err());
    }
}
