//! Host-side parameter store.
//!
//! Parameters are initialized here (GPT-2-style: N(0, 0.02) matrices, zero
//! biases, unit layer-norm gains) and then *uploaded once* to the PJRT
//! device domain by the trainer; afterwards the device buffers are the
//! primary copy and this store only mirrors what the CPU side needs
//! (optimizer state shapes, Zero-baseline full gradients).

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::manifest::Manifest;

/// Flat parameter naming: `wte`, `wpe`, `b{layer}_{name}`, `lnf_g`, `lnf_b`.
#[derive(Debug)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl ParamStore {
    /// Initialize all parameters for the manifest's model config.
    pub fn init(man: &Manifest, seed: u64) -> Result<ParamStore> {
        let mut rng = Rng::new(seed);
        let cfg = &man.config;
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let std = 0.02f32;

        names.push("wte".into());
        tensors.push(Tensor::randn(&[cfg.vocab, cfg.d_model], std, &mut rng));
        names.push("wpe".into());
        tensors.push(Tensor::randn(&[cfg.seq, cfg.d_model], std, &mut rng));

        for layer in 0..cfg.n_layer {
            for (pname, shape) in &man.block_params {
                let t = init_one(pname, shape, cfg.n_layer, std, &mut rng);
                names.push(format!("b{layer}_{pname}"));
                tensors.push(t);
            }
        }
        names.push("lnf_g".into());
        tensors.push(Tensor::full(&[cfg.d_model], 1.0));
        names.push("lnf_b".into());
        tensors.push(Tensor::zeros(&[cfg.d_model]));

        Ok(ParamStore { names, tensors })
    }

    pub fn index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index(name).map(|i| &self.tensors[i])
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Indices of the 12 block params of `layer` in flat order.
    pub fn block_range(&self, man: &Manifest, layer: usize) -> std::ops::Range<usize> {
        let npb = man.block_params.len();
        let start = 2 + layer * npb;
        start..start + npb
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

fn init_one(name: &str, shape: &[usize], n_layer: usize, std: f32, rng: &mut Rng) -> Tensor {
    if name.ends_with("_g") {
        Tensor::full(shape, 1.0)
    } else if name.starts_with("b_") || name.ends_with("_b") {
        Tensor::zeros(shape)
    } else if name == "w_pr" || name == "w_o" {
        // GPT-2 residual-stream scaling: 0.02 / sqrt(2 * n_layer).
        Tensor::randn(shape, std / (2.0 * n_layer as f32).sqrt(), rng)
    } else {
        Tensor::randn(shape, std, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;

    fn tiny_manifest() -> Manifest {
        let dir = std::env::temp_dir().join("lsp_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        // Reuse the manifest sample from the manifest tests.
        let sample = r#"{
          "preset": "tiny",
          "config": {"vocab": 64, "d_model": 32, "n_head": 2, "d_ff": 64,
                     "n_layer": 2, "seq": 16, "batch": 2, "r": 2,
                     "d_frac": 0.5, "n_params": 0},
          "kinds": {},
          "block_params": [{"name": "ln1_g", "shape": [32]},
                           {"name": "ln1_b", "shape": [32]},
                           {"name": "w_qkv", "shape": [32, 96]},
                           {"name": "b_qkv", "shape": [96]}],
          "axpy_lens": [],
          "entries": []
        }"#;
        std::fs::write(dir.join("manifest.json"), sample).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn init_layout_and_kinds() {
        let man = tiny_manifest();
        let ps = ParamStore::init(&man, 7).unwrap();
        // wte, wpe, 2 layers x 4 params, lnf_g, lnf_b
        assert_eq!(ps.len(), 2 + 2 * 4 + 2);
        assert_eq!(ps.names[0], "wte");
        assert_eq!(ps.get("wte").unwrap().shape(), &[64, 32]);
        assert_eq!(ps.names[2], "b0_ln1_g");
        assert_eq!(ps.block_range(&man, 1), 6..10);
        assert_eq!(ps.names[6], "b1_ln1_g");
        // ln gains are ones, biases zeros.
        assert!(ps.get("b0_ln1_g").unwrap().data().iter().all(|&x| x == 1.0));
        assert!(ps.get("b0_b_qkv").unwrap().data().iter().all(|&x| x == 0.0));
        assert_eq!(ps.get("lnf_g").unwrap().len(), 32);
        // Deterministic re-init.
        let ps2 = ParamStore::init(&man, 7).unwrap();
        assert!(ps.get("wte").unwrap().allclose(ps2.get("wte").unwrap(), 0.0));
    }
}
