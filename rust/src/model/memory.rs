//! Memory accounting — the numbers behind Tables 1, 2 and 5 and the
//! Motivation-section Observation.
//!
//! The paper's footprint model (fp16 + Adam): `M_param + M_opt ≈ 8 bytes per
//! parameter` (2 for the fp16 weight, 2 for the fp16 gradient buffer is
//! counted under activations/runtime, and 3x2=6 for Adam's fp32-master+m+v
//! stored compactly; the paper's "8 x #Parameters" headline combines
//! parameters and optimizer state).  We expose the individual pieces so the
//! analyses can print exactly the rows the paper reports.

/// Named model scales used by the paper's analyses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaperModel {
    Llama7B,
    Gpt2_1_3B,
    Gpt2_774M,
    Llama3B,
    DeepseekCoder1_3B,
    DeepseekCoder6_7B,
}

impl PaperModel {
    pub fn params(&self) -> u64 {
        match self {
            PaperModel::Llama7B => 7_000_000_000,
            PaperModel::Gpt2_1_3B => 1_300_000_000,
            PaperModel::Gpt2_774M => 774_000_000,
            PaperModel::Llama3B => 3_000_000_000,
            PaperModel::DeepseekCoder1_3B => 1_300_000_000,
            PaperModel::DeepseekCoder6_7B => 6_700_000_000,
        }
    }

    pub fn n_layers(&self) -> u32 {
        match self {
            PaperModel::Llama7B => 32,
            PaperModel::Gpt2_1_3B => 40,
            PaperModel::Gpt2_774M => 36,
            PaperModel::Llama3B => 26,
            PaperModel::DeepseekCoder1_3B => 24,
            PaperModel::DeepseekCoder6_7B => 32,
        }
    }

    /// Typical hidden size (for Table-2-style per-matrix estimates).
    pub fn hidden(&self) -> u64 {
        match self {
            PaperModel::Llama7B => 4096,
            PaperModel::Gpt2_1_3B => 2048,
            PaperModel::Gpt2_774M => 1280,
            PaperModel::Llama3B => 3200,
            PaperModel::DeepseekCoder1_3B => 2048,
            PaperModel::DeepseekCoder6_7B => 4096,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PaperModel::Llama7B => "llama-7B",
            PaperModel::Gpt2_1_3B => "GPT2-1.3B",
            PaperModel::Gpt2_774M => "GPT2-774M",
            PaperModel::Llama3B => "Llama-3B",
            PaperModel::DeepseekCoder1_3B => "DeepSeek-Coder-1.3B",
            PaperModel::DeepseekCoder6_7B => "DeepSeek-Coder-6.7B",
        }
    }
}

/// Byte sizes of the classic training-memory breakdown.
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub params: u64,
    pub optimizer: u64,
    pub activations: u64,
}

impl MemoryBreakdown {
    /// fp16 weights + Adam optimizer state (paper: M_param + M_opt ≈ 8B/param;
    /// activations estimated per paper Table 1/5 ratios).
    pub fn fp16_adam(n_params: u64, activations: u64) -> Self {
        MemoryBreakdown {
            params: 2 * n_params,     // fp16 weights
            optimizer: 6 * n_params,  // fp32 master + m + v (packed as paper's 3x)
            activations,
        }
    }

    pub fn total(&self) -> u64 {
        self.params + self.optimizer + self.activations
    }
}

/// The Motivation Observation: a schedule doing all compute on a GPU with
/// `gpu_mem` bytes while the model needs `total` bytes must move at least
/// `total - gpu_mem` bytes per iteration.
pub fn min_comm_per_iter(total: u64, gpu_mem: u64) -> u64 {
    total.saturating_sub(gpu_mem)
}

/// Table 2 rows: GPU memory and optimization-space rank for each method.
/// `m, n` — weight matrix dims, `rank` — LoRA/GaLore rank, `d, r` — LSP
/// projector parameters, `beta` — optimizer-state scale factor (3 for Adam),
/// `tau` — number of subspace refreshes so far, `bytes_per` — element size.
#[derive(Debug, Clone, Copy)]
pub struct MethodFootprint {
    /// Extra GPU bytes beyond the frozen pre-trained weight.
    pub gpu_extra_bytes: u64,
    /// Rank of the reachable optimization space.
    pub opt_space_rank: u64,
}

pub fn lora_footprint(m: u64, n: u64, rank: u64, beta: u64, bytes_per: u64) -> MethodFootprint {
    // Trainable A [m, rank], B [rank, n] + optimizer state on both.
    MethodFootprint {
        gpu_extra_bytes: bytes_per * (1 + beta) * rank * (m + n),
        opt_space_rank: rank,
    }
}

pub fn galore_footprint(m: u64, n: u64, rank: u64, beta: u64, tau: u64, gamma1: f64,
                        bytes_per: u64) -> MethodFootprint {
    // Projector P [m, rank] + optimizer state on the projected gradient
    // [rank, n].
    MethodFootprint {
        gpu_extra_bytes: bytes_per * (rank * m + beta * rank * n),
        opt_space_rank: ((tau as f64 * gamma1) * rank as f64).min(m.min(n) as f64) as u64,
    }
}

pub fn lsp_footprint(m: u64, n: u64, d: u64, r: u64, tau: u64, gamma2: f64,
                     bytes_per: u64) -> MethodFootprint {
    // Sparse projectors: (m + n) r values + indices on GPU; the d x d
    // trainable S and its optimizer state live on the *CPU*.
    MethodFootprint {
        gpu_extra_bytes: (bytes_per + 4) * r * (m + n),
        opt_space_rank: ((tau as f64 * gamma2) * d as f64).min(m.min(n) as f64) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_llama7b_numbers() {
        // Paper Table 1: 14GB params, 42GB optimizer state for llama-7B.
        let mb = MemoryBreakdown::fp16_adam(PaperModel::Llama7B.params(), 8 << 30);
        assert_eq!(mb.params, 14_000_000_000);
        assert_eq!(mb.optimizer, 42_000_000_000);
        // Paper: 24GB GPU provides ~37.5% of required memory.
        let frac = (24u64 << 30) as f64 / mb.total() as f64;
        assert!((frac - 0.375).abs() < 0.03, "got {frac}");
    }

    #[test]
    fn table5_gpt2_numbers() {
        // Paper Table 5: 2.6GB params, 7.8GB optimizer state for GPT2-1.3B.
        let mb = MemoryBreakdown::fp16_adam(PaperModel::Gpt2_1_3B.params(), 500 << 20);
        assert_eq!(mb.params, 2_600_000_000);
        assert_eq!(mb.optimizer, 7_800_000_000);
    }

    #[test]
    fn observation_lower_bound() {
        assert_eq!(min_comm_per_iter(64 << 30, 24 << 30), 40 << 30);
        assert_eq!(min_comm_per_iter(10, 20), 0);
    }

    #[test]
    fn lsp_gpu_memory_independent_of_d() {
        // The decoupling claim: LSP's GPU overhead does not grow with d.
        let a = lsp_footprint(2048, 2048, 512, 4, 1, 1.0, 2);
        let b = lsp_footprint(2048, 2048, 1024, 4, 1, 1.0, 2);
        assert_eq!(a.gpu_extra_bytes, b.gpu_extra_bytes);
        assert!(b.opt_space_rank > a.opt_space_rank);
    }

    #[test]
    fn paper_1b_model_example() {
        // Paper: 1B model, hidden 2048, rank-512 subspace, half precision:
        // LoRA needs 4.38GB, GaLore 6.17GB (including the 2GB base model).
        let (m, n, rank) = (2048u64, 2048u64, 512u64);
        let base = 2u64 * 1_000_000_000; // fp16 weights of the 1B model
        let per_matrix_lora = lora_footprint(m, n, rank, 3, 2).gpu_extra_bytes;
        // ~244 matrices of 2048x2048 in a 1B model (1e9 / 2048^2 ~ 238).
        let n_mat = 1_000_000_000 / (m * n);
        let lora_total = base + n_mat * per_matrix_lora;
        let galore_total =
            base + n_mat * galore_footprint(m, n, rank, 3, 1, 1.0, 2).gpu_extra_bytes;
        let lsp_total = base + n_mat * lsp_footprint(m, n, 1024, 4, 1, 1.0, 2).gpu_extra_bytes;
        // Orders must match the paper: LoRA ~4.4GB < GaLore ~6.2GB, LSP ~2GB
        // (exact constants depend on which matrices are adapted; we check
        // the ordering and coarse magnitudes the paper's argument rests on).
        // (The paper's exact 4.38/6.17 GB depend on which matrices are
        // adapted and the optimizer-state dtype; we check coarse magnitudes
        // and the claim that matters: LSP's overhead is far below both.)
        assert!((3.0..8.0).contains(&(lora_total as f64 / 1e9)), "lora {lora_total}");
        assert!((3.0..8.0).contains(&(galore_total as f64 / 1e9)), "galore {galore_total}");
        assert!(lsp_total as f64 / 1e9 < 2.3, "lsp {lsp_total}");
        assert!(lsp_total < lora_total && lsp_total < galore_total);
    }
}
