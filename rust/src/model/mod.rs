//! Model metadata: the artifact manifest (single source of truth for every
//! shape, written by `python/compile/aot.py`), host-side parameter store,
//! and the memory accountant behind Tables 1, 2 and 5.

pub mod manifest;
pub mod memory;
pub mod params;

pub use manifest::{ArgSpec, EntrySpec, KindMeta, Manifest, ModelCfg};
pub use params::ParamStore;
