//! Data pipeline substrate: synthetic corpora stand in for Alpaca /
//! WizardCoder / GLUE (repro substitution — see DESIGN.md).
//!
//! * `Corpus` — a deterministic byte-level language with Markov structure
//!   and repeated "instruction -> response" templates, so a small model has
//!   real signal to fit (loss decreases well below the uniform entropy).
//! * `Batcher` — shuffled (tokens, targets) next-token batches.
//! * `GlueLike` — synthetic sequence-classification tasks with planted
//!   patterns (the Table 3 / Fig. 8 substitute).

use crate::util::rng::Rng;

/// Byte-level tokenizer over a reduced alphabet: ids `0..vocab`.
/// Token 0 is padding/BOS.
#[derive(Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
}

impl Corpus {
    /// Generate a synthetic instruction-tuning corpus.
    ///
    /// Structure = sparse Markov chain over the vocabulary + inserted
    /// template phrases.  The planted regularities give fine-tuning
    /// something learnable; entropy is far below `log(vocab)` so the loss
    /// curve has room to fall.
    pub fn synthetic(vocab: usize, len: usize, seed: u64) -> Corpus {
        assert!(vocab >= 8);
        let mut rng = Rng::new(seed);
        // Sparse bigram table: each context maps to a few likely next tokens.
        let branch = 4usize;
        let table: Vec<i32> = (0..vocab * branch)
            .map(|_| rng.below(vocab) as i32)
            .collect();
        // A handful of template phrases ("instructions") inserted repeatedly.
        let n_templates = 8;
        let templates: Vec<Vec<i32>> = (0..n_templates)
            .map(|_| {
                let tlen = 6 + rng.below(10);
                (0..tlen).map(|_| rng.below(vocab) as i32).collect()
            })
            .collect();

        let mut tokens = Vec::with_capacity(len);
        let mut cur = rng.below(vocab);
        while tokens.len() < len {
            if rng.f32() < 0.05 {
                let t = &templates[rng.below(n_templates)];
                tokens.extend_from_slice(t);
                cur = *t.last().unwrap() as usize;
            } else {
                let choice = table[cur * branch + rng.below(branch)];
                tokens.push(choice);
                cur = choice as usize;
            }
        }
        tokens.truncate(len);
        Corpus { vocab, tokens }
    }

    /// Empirical unigram entropy in nats (sanity metric for tests).
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

/// One (tokens, targets) next-token-prediction batch, both `[batch * seq]`
/// row-major i32.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Shuffled batch iterator over a corpus.
#[derive(Debug)]
pub struct Batcher {
    corpus_tokens: Vec<i32>,
    batch: usize,
    seq: usize,
    rng: Rng,
    offsets: Vec<usize>,
    cursor: usize,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(corpus: &Corpus, batch: usize, seq: usize, seed: u64) -> Batcher {
        let n_windows = (corpus.tokens.len().saturating_sub(seq + 1)) / seq;
        assert!(n_windows >= batch, "corpus too small: {n_windows} windows");
        let mut b = Batcher {
            corpus_tokens: corpus.tokens.clone(),
            batch,
            seq,
            rng: Rng::new(seed),
            offsets: (0..n_windows).map(|w| w * seq).collect(),
            cursor: 0,
            epoch: 0,
        };
        b.shuffle();
        b
    }

    fn shuffle(&mut self) {
        let n = self.offsets.len();
        let perm = self.rng.permutation(n);
        self.offsets = perm.iter().map(|&i| i * self.seq).collect();
        self.cursor = 0;
    }

    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.offsets.len() {
            self.epoch += 1;
            self.shuffle();
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for i in 0..self.batch {
            let off = self.offsets[self.cursor + i];
            tokens.extend_from_slice(&self.corpus_tokens[off..off + self.seq]);
            targets.extend_from_slice(&self.corpus_tokens[off + 1..off + self.seq + 1]);
        }
        self.cursor += self.batch;
        Batch { tokens, targets, batch: self.batch, seq: self.seq }
    }
}

/// Synthetic GLUE-like classification task: a planted token pattern near the
/// sequence start decides the binary label, surrounded by uniform noise.
/// Used as the Table 3 / Fig. 8 substitute (see DESIGN.md substitutions).
#[derive(Debug)]
pub struct GlueLike {
    pub vocab: usize,
    pub seq: usize,
    pattern_a: Vec<i32>,
    pattern_b: Vec<i32>,
    rng: Rng,
}

impl GlueLike {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> GlueLike {
        let mut rng = Rng::new(seed);
        let plen = 4;
        let pattern_a = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        let pattern_b = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        GlueLike { vocab, seq, pattern_a, pattern_b, rng }
    }

    /// Same planted patterns as `new(vocab, seq, seed)` but with the noise
    /// RNG reseeded from `noise_seed`, so two tasks can agree on *what* is
    /// learnable while drawing disjoint example streams.  The eval split
    /// uses this: eval examples must not be a prefix/suffix of the training
    /// stream, or adding eval steps would shift training trajectories.
    pub fn with_noise_stream(vocab: usize, seq: usize, seed: u64, noise_seed: u64) -> GlueLike {
        let mut g = GlueLike::new(vocab, seq, seed);
        g.rng = Rng::new(noise_seed);
        g
    }

    /// Sample one example: (tokens, label). The pattern is placed at a
    /// random early position; everything else is uniform noise.
    pub fn sample(&mut self) -> (Vec<i32>, u8) {
        let label = (self.rng.f32() < 0.5) as u8;
        let pat = if label == 1 { self.pattern_a.clone() } else { self.pattern_b.clone() };
        let mut toks: Vec<i32> =
            (0..self.seq).map(|_| self.rng.below(self.vocab) as i32).collect();
        let pos = self.rng.below(self.seq / 2);
        for (i, &p) in pat.iter().enumerate() {
            if pos + i < self.seq {
                toks[pos + i] = p;
            }
        }
        (toks, label)
    }

    /// As a next-token task: the label token (vocab-1 or vocab-2) is the
    /// target at the final position, so the LM head learns classification.
    pub fn sample_lm(&mut self) -> (Vec<i32>, Vec<i32>) {
        let (mut toks, label) = self.sample();
        let label_tok = (self.vocab - 1 - label as usize) as i32;
        let mut targets = toks[1..].to_vec();
        targets.push(label_tok);
        toks[0] = 0;
        (toks, targets)
    }
}

/// Batch source over the GLUE-like task (`sample_lm` framing), so the
/// trainer can run the Table 3 / Fig. 8 experiment with the same loop.
#[derive(Debug)]
pub struct GlueBatcher {
    task: GlueLike,
    batch: usize,
}

impl GlueBatcher {
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64) -> GlueBatcher {
        GlueBatcher { task: GlueLike::new(vocab, seq, seed), batch }
    }

    /// Same planted patterns (task seed) with an independent noise stream —
    /// see [`GlueLike::with_noise_stream`].
    pub fn with_noise_stream(
        vocab: usize,
        seq: usize,
        batch: usize,
        seed: u64,
        noise_seed: u64,
    ) -> GlueBatcher {
        GlueBatcher { task: GlueLike::with_noise_stream(vocab, seq, seed, noise_seed), batch }
    }

    pub fn next_batch(&mut self) -> Batch {
        let seq = self.task.seq;
        let mut tokens = Vec::with_capacity(self.batch * seq);
        let mut targets = Vec::with_capacity(self.batch * seq);
        for _ in 0..self.batch {
            let (t, tg) = self.task.sample_lm();
            tokens.extend(t);
            targets.extend(tg);
        }
        Batch { tokens, targets, batch: self.batch, seq }
    }
}

/// A batch stream: the LM corpus or the GLUE-like classification task.
#[derive(Debug)]
pub enum DataSource {
    Lm(Batcher),
    Glue(GlueBatcher),
}

impl DataSource {
    pub fn next_batch(&mut self) -> Batch {
        match self {
            DataSource::Lm(b) => b.next_batch(),
            DataSource::Glue(g) => g.next_batch(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic_and_structured() {
        let c1 = Corpus::synthetic(64, 10_000, 1);
        let c2 = Corpus::synthetic(64, 10_000, 1);
        assert_eq!(c1.tokens, c2.tokens);
        assert!(c1.tokens.iter().all(|&t| (0..64).contains(&t)));
        let h = c1.unigram_entropy();
        assert!(h < 4.1, "unigram entropy {h} suggests no structure");
        assert!(h > 1.0, "entropy {h} suspiciously low");
    }

    #[test]
    fn batcher_shapes_and_targets_shifted() {
        let c = Corpus::synthetic(64, 5_000, 2);
        let mut b = Batcher::new(&c, 4, 16, 3);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 64);
        assert_eq!(batch.targets.len(), 64);
        for row in 0..4 {
            for i in 0..15 {
                assert_eq!(batch.targets[row * 16 + i], batch.tokens[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn batcher_epochs_advance() {
        let c = Corpus::synthetic(64, 2_000, 2);
        let mut b = Batcher::new(&c, 4, 16, 3);
        let windows = (2000 - 17) / 16;
        for _ in 0..(windows / 4 + 2) {
            b.next_batch();
        }
        assert!(b.epoch >= 1);
    }

    #[test]
    fn glue_batcher_shapes() {
        let mut gb = GlueBatcher::new(64, 16, 4, 9);
        let b = gb.next_batch();
        assert_eq!(b.tokens.len(), 64);
        assert_eq!(b.targets.len(), 64);
        let mut ds = DataSource::Glue(GlueBatcher::new(64, 16, 2, 9));
        assert_eq!(ds.next_batch().tokens.len(), 32);
    }

    #[test]
    fn noise_stream_split_shares_patterns_but_not_examples() {
        let a = GlueLike::new(64, 32, 5);
        let b = GlueLike::with_noise_stream(64, 32, 5, 0x9e37_79b9);
        assert_eq!(a.pattern_a, b.pattern_a, "task seed must fix the planted patterns");
        assert_eq!(a.pattern_b, b.pattern_b);

        // The eval stream must not reproduce ANY early training batch —
        // with the old shared-stream split, eval batches were literally
        // training batches 50..50+k.
        let mut train = GlueBatcher::new(64, 16, 4, 5);
        let train_batches: Vec<Batch> = (0..100).map(|_| train.next_batch()).collect();
        let mut eval = GlueBatcher::with_noise_stream(64, 16, 4, 5, 5 ^ 0x9e37_79b9);
        for _ in 0..8 {
            let e = eval.next_batch();
            assert!(
                train_batches.iter().all(|t| t.tokens != e.tokens),
                "eval batch duplicated a training batch (contaminated split)"
            );
        }
    }

    #[test]
    fn noise_stream_leaves_primary_stream_untouched() {
        // Constructing (and consuming) an eval batcher must not perturb the
        // training batcher's stream: trajectories are pinned on this.
        let mut solo = GlueBatcher::new(64, 16, 4, 7);
        let solo_batches: Vec<Batch> = (0..10).map(|_| solo.next_batch()).collect();

        let mut train = GlueBatcher::new(64, 16, 4, 7);
        let mut eval = GlueBatcher::with_noise_stream(64, 16, 4, 7, 7 ^ 0x9e37_79b9);
        for _ in 0..5 {
            eval.next_batch();
        }
        for want in &solo_batches {
            let got = train.next_batch();
            assert_eq!(got.tokens, want.tokens);
            assert_eq!(got.targets, want.targets);
        }
    }

    #[test]
    fn glue_like_patterns_differ() {
        let mut g = GlueLike::new(64, 32, 5);
        assert_ne!(g.pattern_a, g.pattern_b);
        let mut ones = 0;
        for _ in 0..200 {
            let (toks, label) = g.sample();
            assert_eq!(toks.len(), 32);
            ones += label as usize;
        }
        assert!((50..150).contains(&ones), "label balance {ones}/200");
        let (toks, targets) = g.sample_lm();
        assert_eq!(toks.len(), 32);
        assert_eq!(targets.len(), 32);
        assert!(targets[31] == 63 || targets[31] == 62);
    }
}
