//! Scoped worker pool for the blocked host kernels.
//!
//! All hot-path kernels (blocked matmul family, sparse compress/decompress)
//! parallelize the same way: the output matrix is split into contiguous row
//! blocks, one per worker, so every worker owns a disjoint `&mut` slice and
//! no locking is needed.  Workers are `std::thread::scope` threads (no
//! external dependencies); the pool width comes from `KernelConfig` and is
//! negotiated with the coordinator, which dedicates its own threads at the
//! schedule level (links + CPU updater).
//!
//! Determinism: splitting the M dimension never changes per-row arithmetic,
//! so results are bit-identical for every worker count (covered by
//! `kernel::tests::threads_do_not_change_results`).

use std::ops::Range;

/// Workers actually worth spawning for `rows` rows given a minimum per-worker
/// granularity (spawning a thread for a handful of rows costs more than the
/// rows themselves).
pub fn effective_workers(threads: usize, rows: usize, min_rows: usize) -> usize {
    let by_work = rows / min_rows.max(1);
    threads.max(1).min(by_work.max(1))
}

/// Run `f` over the `rows * row_len` output buffer `out`, split into
/// contiguous row blocks across up to `threads` scoped workers.
///
/// `f(range, block)` receives the global row range it owns and the matching
/// sub-slice of `out` (`block.len() == range.len() * row_len`).  With one
/// effective worker, `f` runs inline on the caller's thread; otherwise the
/// last block runs on the caller's thread while the rest run on scoped
/// threads.
pub fn par_row_blocks<F>(
    threads: usize,
    rows: usize,
    row_len: usize,
    min_rows: usize,
    out: &mut [f32],
    f: F,
) where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * row_len, "output buffer shape mismatch");
    let workers = effective_workers(threads, rows, min_rows);
    if workers <= 1 {
        f(0..rows, out);
        return;
    }
    let base = rows / workers;
    let extra = rows % workers;
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = out;
        let mut row0 = 0usize;
        for w in 0..workers {
            let take = base + usize::from(w < extra);
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
            rest = tail;
            let range = row0..row0 + take;
            row0 += take;
            if w + 1 == workers {
                // The caller participates instead of idling in scope join.
                f(range, block);
            } else {
                scope.spawn(move || f(range, block));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_workers_bounds() {
        assert_eq!(effective_workers(4, 100, 8), 4);
        assert_eq!(effective_workers(4, 10, 8), 1);
        assert_eq!(effective_workers(4, 17, 8), 2);
        assert_eq!(effective_workers(0, 100, 8), 1);
        assert_eq!(effective_workers(1, 0, 8), 1);
    }

    #[test]
    fn blocks_cover_all_rows_disjointly() {
        for threads in [1usize, 2, 3, 5] {
            let (rows, row_len) = (23usize, 7usize);
            let mut out = vec![0f32; rows * row_len];
            par_row_blocks(threads, rows, row_len, 1, &mut out, |range, block| {
                assert_eq!(block.len(), range.len() * row_len);
                for (local, r) in range.enumerate() {
                    for c in 0..row_len {
                        block[local * row_len + c] += (r * row_len + c) as f32;
                    }
                }
            });
            // Every cell written exactly once with its global index.
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f32, "threads={threads} cell {i}");
            }
        }
    }

    #[test]
    fn empty_output_is_fine() {
        let mut out: Vec<f32> = Vec::new();
        par_row_blocks(4, 0, 5, 1, &mut out, |range, block| {
            assert!(range.is_empty());
            assert!(block.is_empty());
        });
    }
}
