//! Scoped worker pool for the blocked host kernels.
//!
//! All hot-path kernels (blocked matmul family, sparse compress/decompress)
//! parallelize the same way: the output matrix is split into contiguous row
//! blocks, one per worker, so every worker owns a disjoint `&mut` slice and
//! no locking is needed.  Workers are `std::thread::scope` threads (no
//! external dependencies); the pool width comes from `KernelConfig` and is
//! negotiated with the coordinator, which dedicates its own threads at the
//! schedule level (links + CPU updater).
//!
//! Determinism: splitting the M dimension never changes per-row arithmetic,
//! so results are bit-identical for every worker count (covered by
//! `kernel::tests::threads_do_not_change_results`).

use std::ops::Range;

/// Workers actually worth spawning for `rows` rows given a minimum per-worker
/// granularity (spawning a thread for a handful of rows costs more than the
/// rows themselves).
pub fn effective_workers(threads: usize, rows: usize, min_rows: usize) -> usize {
    let by_work = rows / min_rows.max(1);
    threads.max(1).min(by_work.max(1))
}

/// The split policy, in one place: `n` items divided into `workers`
/// contiguous ranges, remainder spread over the first workers.  Consumed by
/// `par_row_blocks` here and by `optim::AdamState::fused_step_with` (which
/// carves four parallel slices along the same ranges).
pub fn split_ranges(workers: usize, n: usize) -> impl Iterator<Item = Range<usize>> {
    let workers = workers.max(1);
    let base = n / workers;
    let extra = n % workers;
    (0..workers).scan(0usize, move |start, w| {
        let take = base + usize::from(w < extra);
        let r = *start..*start + take;
        *start += take;
        Some(r)
    })
}

/// Run `f` over the `rows * row_len` output buffer `out`, split into
/// contiguous row blocks (per `split_ranges`) across up to `threads` scoped
/// workers.
///
/// `f(range, block)` receives the global row range it owns and the matching
/// sub-slice of `out` (`block.len() == range.len() * row_len`).  With one
/// effective worker, `f` runs inline on the caller's thread; otherwise the
/// last block runs on the caller's thread while the rest run on scoped
/// threads.
pub fn par_row_blocks<F>(
    threads: usize,
    rows: usize,
    row_len: usize,
    min_rows: usize,
    out: &mut [f32],
    f: F,
) where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * row_len, "output buffer shape mismatch");
    let workers = effective_workers(threads, rows, min_rows);
    if workers <= 1 {
        f(0..rows, out);
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = out;
        let mut ranges = split_ranges(workers, rows).peekable();
        while let Some(range) = ranges.next() {
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(range.len() * row_len);
            rest = tail;
            if ranges.peek().is_none() {
                // The caller participates instead of idling in scope join.
                f(range, block);
            } else {
                scope.spawn(move || f(range, block));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_workers_bounds() {
        assert_eq!(effective_workers(4, 100, 8), 4);
        assert_eq!(effective_workers(4, 10, 8), 1);
        assert_eq!(effective_workers(4, 17, 8), 2);
        assert_eq!(effective_workers(0, 100, 8), 1);
        assert_eq!(effective_workers(1, 0, 8), 1);
    }

    #[test]
    fn split_ranges_cover_exactly_once() {
        for (workers, n) in [(1usize, 7usize), (3, 7), (4, 4), (5, 17), (2, 0)] {
            let ranges: Vec<_> = split_ranges(workers, n).collect();
            assert_eq!(ranges.len(), workers.max(1));
            // Contiguous, in order, covering 0..n with sizes differing <= 1.
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n);
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced split {sizes:?}");
        }
    }

    #[test]
    fn blocks_cover_all_rows_disjointly() {
        for threads in [1usize, 2, 3, 5] {
            let (rows, row_len) = (23usize, 7usize);
            let mut out = vec![0f32; rows * row_len];
            par_row_blocks(threads, rows, row_len, 1, &mut out, |range, block| {
                assert_eq!(block.len(), range.len() * row_len);
                for (local, r) in range.enumerate() {
                    for c in 0..row_len {
                        block[local * row_len + c] += (r * row_len + c) as f32;
                    }
                }
            });
            // Every cell written exactly once with its global index.
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f32, "threads={threads} cell {i}");
            }
        }
    }

    #[test]
    fn empty_output_is_fine() {
        let mut out: Vec<f32> = Vec::new();
        par_row_blocks(4, 0, 5, 1, &mut out, |range, block| {
            assert!(range.is_empty());
            assert!(block.is_empty());
        });
    }
}
