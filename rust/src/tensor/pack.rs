//! Panel packing for the deep-K GEMM-NN path (§Perf tentpole, part b).
//!
//! At `k >= KernelConfig::pack_min_k` the strided row reads of the plain
//! blocked kernel stop fitting the TLB/cache nicely: each depth step of the
//! register tile touches `MR` cache lines `4k` bytes apart in A, and the
//! B K-block spans `block_k` full rows of the matrix.  This module
//! repacks each K-block once into contiguous panels — A as `kb x MR`
//! column-fragments (`ap[l * MR + r]`), B as `kb x NR` row-fragments
//! (`bp[l * NR + jj]`) — so the micro-kernel streams both operands
//! sequentially.  Pack buffers come from a process-wide `BufPool`, so
//! steady state packs into recycled storage and allocates nothing.
//!
//! **Bit-identity contract** (pinned by `packed_matches_unpacked_bitwise`
//! and the kernel thread-identity test): for every output element the
//! packed sweep performs *exactly* the ops of the un-packed kernel in the
//! same order — same `block_k` depth grid, same ascending-`l` accumulation,
//! one C-add per K-block, SIMD on full-width (`w == NR`) tiles only and
//! the scalar edge micro (same op order as `kernel::micro_nn_edge`)
//! elsewhere.  The un-packed kernel's `block_n` loop only regroups disjoint
//! columns, so dropping it here (each A panel sweeps all N panels) changes
//! nothing per element.  Hence packed vs. un-packed — and any worker split
//! of either — agree bit-for-bit, and `gemm_nn` can flip between the paths
//! on a pure `(k, cfg)` predicate without observable effect beyond speed.

use std::ops::Range;
use std::sync::OnceLock;

use crate::util::bufpool::BufPool;

use super::kernel::{KernelConfig, MR, NR};
use super::{pool, simd};

/// Process-wide pool for pack scratch. Panel sizes are a pure function of
/// the GEMM shape and `block_k`, so the exact-length shelves converge after
/// one pass per shape.
fn pack_pool() -> &'static BufPool {
    static POOL: OnceLock<BufPool> = OnceLock::new();
    POOL.get_or_init(BufPool::new)
}

/// `C += A @ B` via packed panels. Entered from `kernel::gemm_nn` when
/// `k >= cfg.pack_min_k`; same contract as the un-packed kernel (and
/// bit-identical results — see module docs).
pub fn gemm_nn_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    cfg: &KernelConfig,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let bk = cfg.block_k.max(8);
    let n_panels = n.div_ceil(NR);
    let min_rows = cfg.block_m.max(MR);
    let pool_handle = pack_pool();
    let mut l0 = 0;
    while l0 < k {
        let kb = bk.min(k - l0);
        // Pack this B K-block once, before the fan-out; workers share it
        // read-only.
        let mut bp = pool_handle.take_raw(n_panels * kb * NR);
        pack_b(&b[l0 * n..], n, kb, n_panels, &mut bp);
        pool::par_row_blocks(cfg.resolved_threads(), m, n, min_rows, c, |rows, cblock| {
            let row_panels = (rows.end - rows.start).div_ceil(MR);
            let mut ap = pack_pool().take_raw(row_panels * MR * kb);
            pack_a(a, k, rows.clone(), l0, kb, &mut ap);
            for rp in 0..row_panels {
                let i = rows.start + rp * MR;
                let h = MR.min(rows.end - i);
                let a_panel = &ap[rp * MR * kb..(rp + 1) * MR * kb];
                for p in 0..n_panels {
                    let j = p * NR;
                    let w = NR.min(n - j);
                    let b_panel = &bp[p * kb * NR..(p + 1) * kb * NR];
                    let c_sub = &mut cblock[(i - rows.start) * n + j..];
                    // SIMD on full-width tiles only, mirroring the
                    // un-packed dispatch (bit-identity contract).
                    if w == NR && simd::micro_packed(a_panel, b_panel, c_sub, n, kb, h, w) {
                        // handled by the AVX2 tile
                    } else {
                        micro_packed_scalar(a_panel, b_panel, c_sub, n, kb, h, w);
                    }
                }
            }
        });
        l0 += kb;
    }
}

/// Pack B rows `l0..l0+kb` (caller passes `&b[l0*n..]`) into `n_panels`
/// contiguous `kb x NR` panels, zero-padding columns past `n`.  Every slot
/// is written — recycled pool buffers hold arbitrary previous contents.
fn pack_b(b: &[f32], n: usize, kb: usize, n_panels: usize, dst: &mut [f32]) {
    debug_assert!(dst.len() == n_panels * kb * NR);
    for p in 0..n_panels {
        let j = p * NR;
        let w = NR.min(n - j);
        let panel = &mut dst[p * kb * NR..(p + 1) * kb * NR];
        for l in 0..kb {
            let row = &mut panel[l * NR..(l + 1) * NR];
            row[..w].copy_from_slice(&b[l * n + j..l * n + j + w]);
            row[w..].fill(0.0);
        }
    }
}

/// Pack A rows `rows` over depth `l0..l0+kb` into `kb x MR` panels
/// (`panel[l * MR + r]`), zero-padding rows past `rows.end`.  Every slot is
/// written — recycled pool buffers hold arbitrary previous contents.
fn pack_a(a: &[f32], k: usize, rows: Range<usize>, l0: usize, kb: usize, dst: &mut [f32]) {
    let row_panels = (rows.end - rows.start).div_ceil(MR);
    debug_assert!(dst.len() == row_panels * MR * kb);
    for rp in 0..row_panels {
        let i0 = rows.start + rp * MR;
        let h = MR.min(rows.end - i0);
        let panel = &mut dst[rp * MR * kb..(rp + 1) * MR * kb];
        for r in 0..h {
            let arow = &a[(i0 + r) * k + l0..(i0 + r) * k + l0 + kb];
            for (l, &av) in arow.iter().enumerate() {
                panel[l * MR + r] = av;
            }
        }
        for r in h..MR {
            for l in 0..kb {
                panel[l * MR + r] = 0.0;
            }
        }
    }
}

/// Scalar packed tile — op-for-op the same accumulation as
/// `kernel::micro_nn_edge` (ascending `l`, then rows, then columns), just
/// reading from panels. Keep it that way: the bit-identity contract in the
/// module docs depends on it.
fn micro_packed_scalar(
    ap: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    kb: usize,
    h: usize,
    w: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kb {
        let brow = &bp[l * NR..l * NR + w];
        let afrag = &ap[l * MR..l * MR + h];
        for (i, &av) in afrag.iter().enumerate() {
            for (x, &bv) in acc[i][..w].iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for i in 0..h {
        for (cv, &x) in c[i * ldc..i * ldc + w].iter_mut().zip(&acc[i][..w]) {
            *cv += x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::kernel::{gemm_nn, KernelConfig};
    use super::*;
    use crate::util::rng::Rng;

    fn run(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, cfg: &KernelConfig) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        gemm_nn(a, b, &mut c, m, k, n, cfg);
        c
    }

    #[test]
    fn packed_matches_unpacked_bitwise() {
        let mut rng = Rng::new(41);
        // Shapes exercising every edge: m % MR, n % NR, k % block_k, tiny
        // dims smaller than one tile, and multi-K-block depths.
        for &(m, k, n) in
            &[(1, 9, 1), (3, 17, 15), (4, 40, 16), (37, 65, 41), (8, 96, 33), (13, 130, 16)]
        {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            for threads in [1usize, 3] {
                let base = KernelConfig {
                    threads,
                    block_m: 8,
                    block_n: 32,
                    block_k: 32,
                    pack_min_k: 0,
                };
                let packed = KernelConfig { pack_min_k: 1, ..base };
                assert_eq!(
                    run(&a, &b, m, k, n, &base),
                    run(&a, &b, m, k, n, &packed),
                    "m={m} k={k} n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn recycled_pack_buffers_are_fully_overwritten() {
        // Two different shapes that map to the same panel-buffer length:
        // a stale recycled buffer must not leak into the result (padding is
        // rewritten every pack).
        let mut rng = Rng::new(43);
        let cfg = KernelConfig { threads: 1, pack_min_k: 1, ..KernelConfig::default() };
        let (m, k) = (5, 24);
        for &n in &[15usize, 16, 15, 9, 15] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let unpacked = run(&a, &b, m, k, n, &KernelConfig { pack_min_k: 0, ..cfg });
            let packed = run(&a, &b, m, k, n, &cfg);
            assert_eq!(unpacked, packed, "n={n}");
        }
    }

    #[test]
    fn pack_b_pads_and_pack_a_pads() {
        let n = 5; // one panel, 11 padded columns
        let kb = 3;
        let b: Vec<f32> = (0..kb * n).map(|x| x as f32 + 1.0).collect();
        let mut bp = vec![f32::NAN; kb * NR];
        pack_b(&b, n, kb, 1, &mut bp);
        for l in 0..kb {
            assert_eq!(&bp[l * NR..l * NR + n], &b[l * n..(l + 1) * n]);
            assert!(bp[l * NR + n..(l + 1) * NR].iter().all(|&x| x == 0.0));
        }

        let (m, k) = (3, 4); // one panel, one padded row
        let a: Vec<f32> = (0..m * k).map(|x| x as f32 + 1.0).collect();
        let mut ap = vec![f32::NAN; MR * k];
        pack_a(&a, k, 0..m, 0, k, &mut ap);
        for l in 0..k {
            for r in 0..m {
                assert_eq!(ap[l * MR + r], a[r * k + l]);
            }
            assert_eq!(ap[l * MR + m], 0.0, "padded row must be zeroed");
        }
    }
}
