//! Host tensor substrate: dense row-major f32 tensors plus the handful of
//! BLAS-like ops the coordinator needs on its side of the PCIe boundary
//! (baseline projections, fused Adam, projector maintenance, tests).
//!
//! This is deliberately *not* a general autodiff array library — model
//! fwd/bwd runs inside the AOT-compiled XLA artifacts.  The hot paths here
//! (`matmul` family) run on the blocked, register-tiled, multi-threaded
//! kernel substrate in `kernel`/`pool` (§Perf pass); the naive loops
//! survive as `ops::matmul_*_ref` oracles.

use std::fmt;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

pub mod kernel;
pub mod ops;
pub mod pack;
pub mod pool;
pub mod simd;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, std) }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![1, 1], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-matrix {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }

    /// Relative Frobenius error `||self - other||_F / max(||other||_F, eps)`
    /// — the metric the blocked-vs-reference kernel properties use.
    pub fn rel_frob_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let mut num = 0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a as f64) - (*b as f64);
            num += d * d;
        }
        (num.sqrt() / (other.frob_norm() as f64).max(1e-30)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.size_bytes(), 24);
        assert!(Tensor::new(&[2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn reshape_checks_size() {
        let t = Tensor::zeros(&[4, 3]);
        assert!(t.clone().reshape(&[2, 6]).is_ok());
        assert!(t.reshape(&[5, 2]).is_err());
    }

    #[test]
    fn frob_norm_matches_manual() {
        let t = Tensor::new(&[1, 3], vec![3.0, 4.0, 0.0]).unwrap();
        assert!((t.frob_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn randn_std() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[100, 100], 0.02, &mut rng);
        let var = t.data().iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        assert!((var.sqrt() - 0.02).abs() < 0.002);
    }
}
