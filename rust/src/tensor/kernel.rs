//! Blocked, register-tiled GEMM kernels — the host hot-path substrate.
//!
//! BLIS-style structure without explicit packing (row-major f32 needs none
//! at these sizes): a `MR x NR` register-tile micro-kernel sits under cache
//! blocking over K (`block_k`) and N (`block_n`), and the M dimension is
//! split across the scoped worker pool (`tensor::pool`).  The B operand is
//! touched `NR` contiguous floats at a time (one cache line), so a K-block
//! of B occupies `block_k` cache lines and stays resident while the `MR`
//! A-rows stream through registers.
//!
//! The naive triple loops survive as `ops::matmul_*_ref` oracles; property
//! tests assert blocked == reference to within 1e-4 relative Frobenius
//! error across randomized shapes and configs.
//!
//! ## SIMD dispatch and the tolerance contract
//!
//! Since the §Perf tentpole, every full-width (`w == NR`) register tile
//! first offers itself to the AVX2+FMA micro-kernels in `tensor::simd`;
//! the scalar micro-kernels below remain the always-compiled fallback
//! (non-x86-64, CPUs without AVX2/FMA, or `LSP_FORCE_SCALAR=1`).  For
//! `k >= pack_min_k` the NN kernel additionally routes through
//! `tensor::pack`, which streams contiguous `kb x MR` / `kb x NR` panels.
//!
//! The resulting **tolerance contract**, pinned by the property tests:
//!
//! * Blocked (scalar or SIMD, packed or not) vs. the naive `_ref` oracles:
//!   equal to within **1e-4 relative Frobenius** error.  Three rounding
//!   regimes coexist — the oracles' single running sum, the scalar micros'
//!   blocked mul+add chains (`dot_lanes`' 8 independent accumulators in the
//!   NT kernel), and the SIMD micros' FMA chains, which contract mul+add
//!   into one rounding per depth step.  FMA also rounds *differently on
//!   denormal/NaN-adjacent inputs* (no intermediate flush of the product),
//!   which is why the oracles compare with a relative tolerance rather
//!   than bit equality — see `ops::nt_ref_zero_skip_keeps_exact_semantics`
//!   for the one place (`matmul_nt_ref`'s zero-skip) where exactness *is*
//!   asserted, and `ops::nt_ref_zero_skip_nan_denormal_audit` for the
//!   NaN/denormal corners of that skip.
//! * Across worker splits (`threads`): **bit-for-bit identical**, in every
//!   regime.  The M split only regroups rows; per-row arithmetic is
//!   h-agnostic in both the scalar and SIMD micros, SIMD is gated on the
//!   thread-independent `w == NR` j-grid, and the pack decision depends
//!   only on `(k, cfg)`.
//! * Packed vs. un-packed, same process configuration: **bit-for-bit
//!   identical** — the packed sweep preserves each output element's
//!   accumulation order exactly (panel edges use the scalar edge micro in
//!   both paths).
//!
//! `KernelConfig` is the knob surface: it is parsed by `config/`
//! (`--kernel-threads`, `kernel_block_*`) and negotiated *per trainer
//! instance* by the coordinator (`PipelineCtx::new` reserves the
//! schedule-level threads and threads the result through the `*_with`
//! entry points — nothing is installed process-wide on the training path,
//! so trainers with different configs coexist in one process).  The
//! process-wide `install`/`current` pair remains as the default for
//! standalone callers (benches, analyses) using the non-`_with` wrappers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::{pack, pool, simd};

/// Rows of C per register tile.
pub const MR: usize = 4;
/// Columns of C per register tile (one 64-byte cache line of f32).
pub const NR: usize = 16;

/// Shape of the blocked kernels: worker width plus cache-block sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Worker threads splitting the M dimension. `0` = auto-detect
    /// (available parallelism, capped at 8).
    pub threads: usize,
    /// Minimum rows of C per worker (also the split granularity).
    pub block_m: usize,
    /// Columns of C per cache block (rounded up to `NR` internally).
    pub block_n: usize,
    /// Depth (K) per cache block.
    pub block_k: usize,
    /// Minimum K at which `gemm_nn` routes through the panel-packing path
    /// (`tensor::pack`). `0` disables packing entirely.
    pub pack_min_k: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig { threads: 0, block_m: 32, block_n: 256, block_k: 256, pack_min_k: 2048 }
    }
}

impl KernelConfig {
    pub fn with_threads(threads: usize) -> KernelConfig {
        KernelConfig { threads, ..KernelConfig::default() }
    }

    pub fn single_threaded() -> KernelConfig {
        KernelConfig::with_threads(1)
    }

    /// Resolve `threads == 0` to the machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        static AUTO: OnceLock<usize> = OnceLock::new();
        *AUTO.get_or_init(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
        })
    }

    /// Coordinator negotiation: the trainer dedicates `reserved` threads at
    /// the schedule level (link threads + CPU updater), so the kernels get
    /// the remainder, never less than one.
    pub fn negotiated(&self, reserved: usize) -> KernelConfig {
        let threads = self.resolved_threads().saturating_sub(reserved).max(1);
        KernelConfig { threads, ..*self }
    }
}

// Process-wide config consumed by the `ops::matmul*` / `sparse` entry
// points. 0 in a block slot means "default"; threads 0 already means auto.
static G_THREADS: AtomicUsize = AtomicUsize::new(0);
static G_BLOCK_M: AtomicUsize = AtomicUsize::new(0);
static G_BLOCK_N: AtomicUsize = AtomicUsize::new(0);
static G_BLOCK_K: AtomicUsize = AtomicUsize::new(0);
// pack_min_k legitimately takes the value 0 ("disabled"), so the slot
// stores `pack_min_k + 1` and keeps raw 0 as the "unset" sentinel.
static G_PACK_MIN_K: AtomicUsize = AtomicUsize::new(0);

/// Install `cfg` as the process-wide kernel configuration.
pub fn install(cfg: KernelConfig) {
    G_THREADS.store(cfg.threads, Ordering::Relaxed);
    G_BLOCK_M.store(cfg.block_m, Ordering::Relaxed);
    G_BLOCK_N.store(cfg.block_n, Ordering::Relaxed);
    G_BLOCK_K.store(cfg.block_k, Ordering::Relaxed);
    G_PACK_MIN_K.store(cfg.pack_min_k + 1, Ordering::Relaxed);
}

/// The process-wide kernel configuration (defaults where unset).
pub fn current() -> KernelConfig {
    let d = KernelConfig::default();
    let or = |v: usize, dv: usize| if v == 0 { dv } else { v };
    KernelConfig {
        threads: G_THREADS.load(Ordering::Relaxed),
        block_m: or(G_BLOCK_M.load(Ordering::Relaxed), d.block_m),
        block_n: or(G_BLOCK_N.load(Ordering::Relaxed), d.block_n),
        block_k: or(G_BLOCK_K.load(Ordering::Relaxed), d.block_k),
        pack_min_k: match G_PACK_MIN_K.load(Ordering::Relaxed) {
            0 => d.pack_min_k,
            v => v - 1,
        },
    }
}

// ---- C = A @ B ----------------------------------------------------------

/// Accumulate `C += A @ B` (A `[m,k]`, B `[k,n]`, C `[m,n]`, row-major).
pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, cfg: &KernelConfig) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Deep-K shapes stream packed panels instead of strided rows. The
    // decision depends only on (k, cfg), never on the worker split, so the
    // threads-bit-identity invariant is preserved.
    if cfg.pack_min_k > 0 && k >= cfg.pack_min_k {
        pack::gemm_nn_packed(a, b, c, m, k, n, cfg);
        return;
    }
    let min_rows = cfg.block_m.max(MR);
    pool::par_row_blocks(cfg.resolved_threads(), m, n, min_rows, c, |rows, cblock| {
        gemm_nn_rows(a, b, cblock, rows.start, rows.end, k, n, cfg);
    });
}

fn gemm_nn_rows(
    a: &[f32],
    b: &[f32],
    c: &mut [f32], // rows r0..r1 of C
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    cfg: &KernelConfig,
) {
    let bk = cfg.block_k.max(8);
    let bn = cfg.block_n.max(NR);
    let mut l0 = 0;
    while l0 < k {
        let kb = bk.min(k - l0);
        let mut j0 = 0;
        while j0 < n {
            let nb = bn.min(n - j0);
            let mut i = r0;
            while i < r1 {
                let h = MR.min(r1 - i);
                let mut j = j0;
                while j < j0 + nb {
                    let w = NR.min(j0 + nb - j);
                    let a_sub = &a[i * k + l0..];
                    let b_sub = &b[l0 * n + j..];
                    let c_sub = &mut c[(i - r0) * n + j..];
                    // SIMD only on full-width tiles: the w grid is derived
                    // from (n, cfg) and thus identical for every worker, so
                    // the dispatch cannot vary with the thread split.
                    if w == NR && simd::micro_nn(a_sub, k, b_sub, n, c_sub, n, kb, h) {
                        // handled by the AVX2 tile
                    } else if h == MR && w == NR {
                        micro_nn_full(a_sub, k, b_sub, n, c_sub, n, kb);
                    } else {
                        micro_nn_edge(a_sub, k, b_sub, n, c_sub, n, kb, h, w);
                    }
                    j += w;
                }
                i += h;
            }
            j0 += nb;
        }
        l0 += kb;
    }
}

/// Full `MR x NR` tile: C_tile += A_tile @ B_tile over `kb` depth steps.
/// `a` starts at A[i][l0] (row stride `lda`), `b` at B[l0][j] (stride
/// `ldb`), `c` at C[i][j] (stride `ldc`).
#[inline]
fn micro_nn_full(a: &[f32], lda: usize, b: &[f32], ldb: usize, c: &mut [f32], ldc: usize, kb: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kb {
        let brow = &b[l * ldb..l * ldb + NR];
        for i in 0..MR {
            let av = a[i * lda + l];
            for (x, &bv) in acc[i].iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (i, lane) in acc.iter().enumerate() {
        for (cv, &x) in c[i * ldc..i * ldc + NR].iter_mut().zip(lane) {
            *cv += x;
        }
    }
}

/// Partial tile (`h <= MR`, `w <= NR`) for the M/N edges.
#[inline]
fn micro_nn_edge(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    kb: usize,
    h: usize,
    w: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kb {
        let brow = &b[l * ldb..l * ldb + w];
        for i in 0..h {
            let av = a[i * lda + l];
            for (x, &bv) in acc[i][..w].iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for i in 0..h {
        for (cv, &x) in c[i * ldc..i * ldc + w].iter_mut().zip(&acc[i][..w]) {
            *cv += x;
        }
    }
}

// ---- C = A^T @ B --------------------------------------------------------

/// Accumulate `C += A^T @ B` (A `[k,m]`, B `[k,n]`, C `[m,n]`) without
/// materializing the transpose. The register tile reads `MR` *contiguous*
/// A elements per depth step (a row fragment of A is a column fragment of
/// A^T), which makes this the best-vectorizing kernel of the family.
pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize, cfg: &KernelConfig) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let min_rows = cfg.block_m.max(MR);
    pool::par_row_blocks(cfg.resolved_threads(), m, n, min_rows, c, |rows, cblock| {
        gemm_tn_rows(a, b, cblock, rows.start, rows.end, k, m, n, cfg);
    });
}

#[allow(clippy::too_many_arguments)]
fn gemm_tn_rows(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    m: usize,
    n: usize,
    cfg: &KernelConfig,
) {
    let bk = cfg.block_k.max(8);
    let bn = cfg.block_n.max(NR);
    let mut l0 = 0;
    while l0 < k {
        let kb = bk.min(k - l0);
        let mut j0 = 0;
        while j0 < n {
            let nb = bn.min(n - j0);
            let mut i = r0;
            while i < r1 {
                let h = MR.min(r1 - i);
                let mut j = j0;
                while j < j0 + nb {
                    let w = NR.min(j0 + nb - j);
                    let a_sub = &a[l0 * m + i..];
                    let b_sub = &b[l0 * n + j..];
                    let c_sub = &mut c[(i - r0) * n + j..];
                    if w == NR && simd::micro_tn(a_sub, m, b_sub, n, c_sub, n, kb, h) {
                        // handled by the AVX2 tile
                    } else {
                        micro_tn(a_sub, m, b_sub, n, c_sub, n, kb, h, w);
                    }
                    j += w;
                }
                i += h;
            }
            j0 += nb;
        }
        l0 += kb;
    }
}

/// `h x w` tile of C += A^T B: `a` starts at A[l0][i] (row stride `lda ==
/// m`), so the `h` A-values per depth step are contiguous.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_tn(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    kb: usize,
    h: usize,
    w: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kb {
        let afrag = &a[l * lda..l * lda + h];
        let brow = &b[l * ldb..l * ldb + w];
        for (i, &av) in afrag.iter().enumerate() {
            for (x, &bv) in acc[i][..w].iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for i in 0..h {
        for (cv, &x) in c[i * ldc..i * ldc + w].iter_mut().zip(&acc[i][..w]) {
            *cv += x;
        }
    }
}

// ---- C = A @ B^T --------------------------------------------------------

/// Lanes for the dot-product accumulation in the NT kernel.
const LANES: usize = 8;

/// Accumulate `C += A @ B^T` (A `[m,k]`, B `[n,k]`, C `[m,n]`). Both
/// operands are read along contiguous rows; B rows are processed in
/// `block_n`-row blocks so a block stays cache-resident across consecutive
/// A rows, and each dot product accumulates in `LANES` independent lanes so
/// the compiler can vectorize it.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, cfg: &KernelConfig) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        return;
    }
    let min_rows = cfg.block_m.max(MR);
    // Keep the B block within ~256 KiB so it survives the i sweep.
    let bn = cfg.block_n.min((1 << 16) / k.max(1)).max(4);
    pool::par_row_blocks(cfg.resolved_threads(), m, n, min_rows, c, |rows, cblock| {
        let r0 = rows.start;
        // B-block loop OUTSIDE the row loop so the block actually stays
        // cache-resident across consecutive A rows.
        let mut j0 = 0;
        while j0 < n {
            let nb = bn.min(n - j0);
            for i in rows.clone() {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut cblock[(i - r0) * n..(i - r0 + 1) * n];
                for j in j0..j0 + nb {
                    let brow = &b[j * k..(j + 1) * k];
                    crow[j] += simd::dot(arow, brow);
                }
            }
            j0 += nb;
        }
    });
}

/// Dot product with `LANES` independent accumulators (vectorizable; float
/// summation order therefore differs from the scalar reference, which is
/// why the oracles compare with a relative Frobenius tolerance).
#[inline]
pub fn dot_lanes(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let main = n - n % LANES;
    let mut acc = [0.0f32; LANES];
    let mut l = 0;
    while l < main {
        let xs = &x[l..l + LANES];
        let ys = &y[l..l + LANES];
        for s in 0..LANES {
            acc[s] += xs[s] * ys[s];
        }
        l += LANES;
    }
    let mut tail = 0.0f32;
    for l in main..n {
        tail += x[l] * y[l];
    }
    acc.iter().sum::<f32>() + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_config_defaults() {
        let d = KernelConfig::default();
        assert_eq!(d.threads, 0, "default is auto-detect");
        assert!(d.resolved_threads() >= 1 && d.resolved_threads() <= 8);
        assert!(d.block_m >= MR);
        assert_eq!(d.block_n % NR, 0, "block_n aligned to the register tile");
        assert!(d.block_k >= 8);
        assert_eq!(d.pack_min_k, 2048, "packing defaults to the deep-K regime");
        assert_eq!(KernelConfig::single_threaded().threads, 1);
        assert_eq!(KernelConfig::single_threaded().resolved_threads(), 1);
        // Negotiation never starves the kernels.
        assert_eq!(KernelConfig::with_threads(4).negotiated(3).threads, 1);
        assert_eq!(KernelConfig::with_threads(4).negotiated(99).threads, 1);
        assert_eq!(KernelConfig::with_threads(6).negotiated(2).threads, 4);
    }

    #[test]
    fn current_falls_back_to_defaults() {
        // Unset slots read as defaults (threads 0 stays "auto").
        let cur = current();
        assert!(cur.block_m > 0 && cur.block_n > 0 && cur.block_k > 0);
    }

    #[test]
    fn threads_do_not_change_results() {
        // threads = 1 must reproduce the multi-threaded (and vice versa)
        // results bit-for-bit: the M split never alters per-row arithmetic.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let (m, k, n) = (37, 29, 41);
        let a: Vec<f32> = rng.normal_vec(m * k, 1.0);
        let b: Vec<f32> = rng.normal_vec(k * n, 1.0);
        let bt: Vec<f32> = rng.normal_vec(n * k, 1.0);
        let at: Vec<f32> = rng.normal_vec(k * m, 1.0);
        for threads in [2usize, 3, 5] {
            let c1 = KernelConfig { threads: 1, block_m: 8, ..KernelConfig::default() };
            let cn = KernelConfig { threads, block_m: 8, ..KernelConfig::default() };
            let mut c_one = vec![0f32; m * n];
            let mut c_many = vec![0f32; m * n];
            gemm_nn(&a, &b, &mut c_one, m, k, n, &c1);
            gemm_nn(&a, &b, &mut c_many, m, k, n, &cn);
            assert_eq!(c_one, c_many, "nn threads={threads}");
            // The packed path must uphold the same invariant (pack_min_k=1
            // forces it at this small k).
            let p1 = KernelConfig { pack_min_k: 1, ..c1 };
            let pn = KernelConfig { pack_min_k: 1, ..cn };
            let mut p_one = vec![0f32; m * n];
            let mut p_many = vec![0f32; m * n];
            gemm_nn(&a, &b, &mut p_one, m, k, n, &p1);
            gemm_nn(&a, &b, &mut p_many, m, k, n, &pn);
            assert_eq!(p_one, p_many, "nn packed threads={threads}");
            let mut t_one = vec![0f32; m * n];
            let mut t_many = vec![0f32; m * n];
            gemm_tn(&at, &b, &mut t_one, k, m, n, &c1);
            gemm_tn(&at, &b, &mut t_many, k, m, n, &cn);
            assert_eq!(t_one, t_many, "tn threads={threads}");
            let mut n_one = vec![0f32; m * n];
            let mut n_many = vec![0f32; m * n];
            gemm_nt(&a, &bt, &mut n_one, m, k, n, &c1);
            gemm_nt(&a, &bt, &mut n_many, m, k, n, &cn);
            assert_eq!(n_one, n_many, "nt threads={threads}");
        }
    }

    #[test]
    fn dot_lanes_matches_scalar() {
        let x: Vec<f32> = (0..37).map(|i| (i as f32) * 0.25 - 3.0).collect();
        let y: Vec<f32> = (0..37).map(|i| 1.5 - (i as f32) * 0.125).collect();
        let scalar: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot_lanes(&x, &y) - scalar).abs() < 1e-3);
    }
}
