//! Explicit-SIMD micro-kernels behind runtime feature detection — the
//! §Perf tentpole.
//!
//! Everything `std::arch` lives in this one module: the x86-64 AVX2+FMA
//! register tiles used under [`super::kernel`]'s blocked GEMMs (and the
//! packed variants in [`super::pack`]), the FMA-free fused-Adam span used
//! by `optim::adam_span`, and the bf16 wire-codec conversion loops used by
//! `codec::bf16`.  Every entry point is a safe wrapper that re-checks
//! [`avx2_active`] and reports whether it ran, so callers keep their scalar
//! bodies as the always-available fallback — on non-x86-64 targets the
//! wrappers compile to "did nothing" and the scalar paths are the only
//! paths.
//!
//! Dispatch policy (also documented in `tensor/kernel.rs` module docs):
//!
//! * **GEMM tiles** (`micro_nn` / `micro_tn` / `micro_packed` / `dot`) use
//!   FMA, which contracts the scalar `mul` + `add` rounding steps into one.
//!   Results therefore differ from the scalar micro-kernels in low-order
//!   bits; the property tests compare both against the naive oracles with
//!   the repo-wide 1e-4 relative Frobenius tolerance.  Within ONE process
//!   configuration the dispatch is deterministic and per-output-row
//!   arithmetic never depends on the worker split, so thread counts still
//!   never change results bit-for-bit.
//! * **Fused Adam** (`adam_span_prefix`) is deliberately FMA-free: the
//!   vector body uses only correctly-rounded IEEE elementwise ops
//!   (mul/add/sqrt/div), so every lane is bit-identical to the scalar loop
//!   and the parallel/chunked bit-identity invariants of `optim` survive
//!   the SIMD dispatch unchanged.
//! * **bf16 encode/decode** uses integer lane ops that replicate the scalar
//!   round-to-nearest-even bit arithmetic exactly — byte-identical wires.
//!
//! `LSP_FORCE_SCALAR=1` (env, read once) or [`set_force_scalar`] (bench
//! hook) disable the SIMD paths at runtime so the scalar fallback stays
//! exercised on every machine (`scripts/check.sh` runs a forced-scalar test
//! lane).  Unit tests never toggle the flag — the flag is process-global
//! and the parity tests instead compare the SIMD wrappers directly against
//! the scalar bodies, which is race-free.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use crate::util::bufpool::PooledBytes;

use super::kernel::{dot_lanes, MR, NR};

/// Bench/tune hook: force the scalar fallbacks even when AVX2+FMA is
/// available.  The `LSP_FORCE_SCALAR=1` environment variable (read once)
/// has the same effect and cannot be un-forced by this call.
pub fn set_force_scalar(force: bool) {
    FORCE_SCALAR.store(force, Ordering::Relaxed);
}

/// True when the AVX2+FMA paths are compiled in, the CPU reports both
/// features, and neither `LSP_FORCE_SCALAR=1` nor [`set_force_scalar`]
/// disabled them.
pub fn avx2_active() -> bool {
    detected() && !env_force_scalar() && !FORCE_SCALAR.load(Ordering::Relaxed)
}

/// The dispatch label benches and the tuner record next to their numbers.
pub fn active_impl_name() -> &'static str {
    if avx2_active() {
        "avx2"
    } else {
        "scalar"
    }
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

fn env_force_scalar() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| std::env::var("LSP_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false))
}

fn detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static DET: OnceLock<bool> = OnceLock::new();
        *DET.get_or_init(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    false
}

/// Coefficients for one fused-Adam span, passed by value so the SIMD body
/// and the scalar loop are guaranteed to splat identical constants.
#[derive(Debug, Clone, Copy)]
pub struct AdamCoefs {
    pub beta1: f32,
    pub om_b1: f32,
    pub beta2: f32,
    pub om_b2: f32,
    pub eps: f32,
    pub bc1: f32,
    pub bc2_sqrt: f32,
}

// ---- safe wrappers ------------------------------------------------------

/// AVX2 `h x NR` GEMM-NN tile (`w == NR` only — column edges stay scalar so
/// the j-grid arithmetic is identical for every worker split).  Returns
/// `false` when the SIMD path is unavailable; the caller must then run the
/// scalar micro-kernel.
#[allow(clippy::too_many_arguments)]
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn micro_nn(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    kb: usize,
    h: usize,
) -> bool {
    debug_assert!(h >= 1 && h <= MR && kb >= 1);
    debug_assert!((h - 1) * lda + kb <= a.len());
    debug_assert!((kb - 1) * ldb + NR <= b.len());
    debug_assert!((h - 1) * ldc + NR <= c.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: extents checked above; feature presence checked by
        // avx2_active().
        unsafe { x86::micro_nn_avx2(a, lda, b, ldb, c, ldc, kb, h) };
        return true;
    }
    false
}

/// AVX2 `h x NR` GEMM-TN tile (`a` starts at A[l0][i], row stride `lda`, so
/// the `h` A-values per depth step are contiguous).  `w == NR` only, as in
/// [`micro_nn`].
#[allow(clippy::too_many_arguments)]
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn micro_tn(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    kb: usize,
    h: usize,
) -> bool {
    debug_assert!(h >= 1 && h <= MR && kb >= 1);
    debug_assert!((kb - 1) * lda + h <= a.len());
    debug_assert!((kb - 1) * ldb + NR <= b.len());
    debug_assert!((h - 1) * ldc + NR <= c.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: extents checked above; feature presence checked by
        // avx2_active().
        unsafe { x86::micro_tn_avx2(a, lda, b, ldb, c, ldc, kb, h) };
        return true;
    }
    false
}

/// AVX2 tile over *packed* panels (`ap`: `kb x MR` A panel, `bp`: `kb x NR`
/// B panel, both zero-padded — see `tensor::pack`).  Handles `w < NR`
/// column edges itself: the padded lanes are computed and discarded, which
/// is safe because the pack step zeroed them.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn micro_packed(
    ap: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    kb: usize,
    h: usize,
    w: usize,
) -> bool {
    debug_assert!(h >= 1 && h <= MR && w >= 1 && w <= NR && kb >= 1);
    debug_assert!(kb * MR <= ap.len());
    debug_assert!(kb * NR <= bp.len());
    debug_assert!((h - 1) * ldc + w <= c.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: extents checked above; feature presence checked by
        // avx2_active().
        unsafe { x86::micro_packed_avx2(ap, bp, c, ldc, kb, h, w) };
        return true;
    }
    false
}

/// Dot product: AVX2+FMA two-accumulator body when active, otherwise the
/// scalar [`dot_lanes`].  Per-(i,j) arithmetic, so worker splits never see
/// a mixed path within one call site's configuration.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: equal lengths checked; feature presence checked.
        return unsafe { x86::dot_avx2(x, y) };
    }
    dot_lanes(x, y)
}

/// Run the fused-Adam body over the largest 8-aligned prefix of the span,
/// returning how many elements were processed (0 when SIMD is inactive —
/// the caller's scalar loop then covers everything).  FMA-free: bitwise
/// identical to the scalar body, so the prefix boundary is unobservable.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn adam_span_prefix(
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    d: &mut [f32],
    coefs: AdamCoefs,
) -> usize {
    debug_assert!(g.len() == m.len() && g.len() == v.len() && g.len() == d.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() && g.len() >= 8 {
        // SAFETY: equal lengths checked; feature presence checked.
        return unsafe { x86::adam_span_avx2(g, m, v, d, coefs) };
    }
    0
}

/// Encode the largest 8-aligned prefix of `src` as little-endian bf16 pairs
/// appended to `dst`, returning elements consumed (0 when inactive).  The
/// integer lane ops replicate `codec::bf16::f32_to_bf16_bits` exactly
/// (round-to-nearest-even, NaN quieting included) — byte-identical wires.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn bf16_encode_prefix(src: &[f32], dst: &mut PooledBytes) -> usize {
    #[cfg(target_arch = "x86_64")]
    if avx2_active() && src.len() >= 8 {
        // SAFETY: feature presence checked; writes go through the safe
        // append API.
        return unsafe { x86::bf16_encode_avx2(src, dst) };
    }
    0
}

/// Decode the largest 8-aligned prefix of a bf16 wire payload into `dst`,
/// returning elements produced (0 when inactive).  Bit-exact (a bf16
/// decode is a 16-bit shift).  `src.len()` must equal `dst.len() * 2`.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn bf16_decode_prefix(src: &[u8], dst: &mut [f32]) -> usize {
    debug_assert_eq!(src.len(), dst.len() * 2);
    #[cfg(target_arch = "x86_64")]
    if avx2_active() && dst.len() >= 8 {
        // SAFETY: length relation checked; feature presence checked.
        return unsafe { x86::bf16_decode_avx2(src, dst) };
    }
    0
}

// ---- x86-64 bodies ------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use crate::util::bufpool::PooledBytes;

    use super::super::kernel::{MR, NR};
    use super::AdamCoefs;

    /// SAFETY: caller checked AVX2+FMA and the slice extents (see the
    /// wrapper's debug asserts — `a[(h-1)*lda + kb - 1]`,
    /// `b[(kb-1)*ldb + NR - 1]` and `c[(h-1)*ldc + NR - 1]` must exist).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn micro_nn_avx2(
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        c: &mut [f32],
        ldc: usize,
        kb: usize,
        h: usize,
    ) {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        for l in 0..kb {
            let brow = bp.add(l * ldb);
            let b0 = _mm256_loadu_ps(brow);
            let b1 = _mm256_loadu_ps(brow.add(8));
            // The i-loop body depends only on i, so per-row results are
            // identical for every h — h-edge tiles (worker-split dependent)
            // cannot diverge from full tiles.
            for (i, lane) in acc.iter_mut().take(h).enumerate() {
                let av = _mm256_set1_ps(*ap.add(i * lda + l));
                lane[0] = _mm256_fmadd_ps(av, b0, lane[0]);
                lane[1] = _mm256_fmadd_ps(av, b1, lane[1]);
            }
        }
        store_tiles(&acc, c, ldc, h);
    }

    /// SAFETY: as `micro_nn_avx2`, with `a[(kb-1)*lda + h - 1]` the A
    /// extent (contiguous row fragments of A = column fragments of A^T).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn micro_tn_avx2(
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        c: &mut [f32],
        ldc: usize,
        kb: usize,
        h: usize,
    ) {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        for l in 0..kb {
            let brow = bp.add(l * ldb);
            let b0 = _mm256_loadu_ps(brow);
            let b1 = _mm256_loadu_ps(brow.add(8));
            let afrag = ap.add(l * lda);
            for (i, lane) in acc.iter_mut().take(h).enumerate() {
                let av = _mm256_set1_ps(*afrag.add(i));
                lane[0] = _mm256_fmadd_ps(av, b0, lane[0]);
                lane[1] = _mm256_fmadd_ps(av, b1, lane[1]);
            }
        }
        store_tiles(&acc, c, ldc, h);
    }

    /// SAFETY: caller checked AVX2+FMA, `ap.len() >= kb * MR`,
    /// `bp.len() >= kb * NR` and the C extent.  Padded lanes (`w < NR`) are
    /// computed against the pack step's zeros and never stored.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn micro_packed_avx2(
        ap: &[f32],
        bp: &[f32],
        c: &mut [f32],
        ldc: usize,
        kb: usize,
        h: usize,
        w: usize,
    ) {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        let app = ap.as_ptr();
        let bpp = bp.as_ptr();
        for l in 0..kb {
            let brow = bpp.add(l * NR);
            let b0 = _mm256_loadu_ps(brow);
            let b1 = _mm256_loadu_ps(brow.add(8));
            let afrag = app.add(l * MR);
            for (i, lane) in acc.iter_mut().take(h).enumerate() {
                let av = _mm256_set1_ps(*afrag.add(i));
                lane[0] = _mm256_fmadd_ps(av, b0, lane[0]);
                lane[1] = _mm256_fmadd_ps(av, b1, lane[1]);
            }
        }
        if w == NR {
            store_tiles(&acc, c, ldc, h);
        } else {
            let mut tmp = [0f32; NR];
            for (i, lane) in acc.iter().take(h).enumerate() {
                _mm256_storeu_ps(tmp.as_mut_ptr(), lane[0]);
                _mm256_storeu_ps(tmp.as_mut_ptr().add(8), lane[1]);
                let crow = c.as_mut_ptr().add(i * ldc);
                for (jj, &x) in tmp.iter().take(w).enumerate() {
                    *crow.add(jj) += x;
                }
            }
        }
    }

    /// `C_tile += acc` for `h` rows of `NR` columns.
    #[target_feature(enable = "avx2")]
    unsafe fn store_tiles(acc: &[[__m256; 2]; MR], c: &mut [f32], ldc: usize, h: usize) {
        for (i, lane) in acc.iter().take(h).enumerate() {
            let crow = c.as_mut_ptr().add(i * ldc);
            _mm256_storeu_ps(crow, _mm256_add_ps(_mm256_loadu_ps(crow), lane[0]));
            _mm256_storeu_ps(crow.add(8), _mm256_add_ps(_mm256_loadu_ps(crow.add(8)), lane[1]));
        }
    }

    /// SAFETY: caller checked AVX2+FMA and `x.len() == y.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len();
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let main = n - n % 16;
        let mut i = 0;
        while i < main {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 8)),
                _mm256_loadu_ps(yp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if n - i >= 8 {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let q = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
        let q = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let q = _mm_add_ss(q, _mm_shuffle_ps::<1>(q, q));
        let mut sum = _mm_cvtss_f32(q);
        while i < n {
            sum += *xp.add(i) * *yp.add(i);
            i += 1;
        }
        sum
    }

    /// SAFETY: caller checked AVX2 and equal span lengths.  FMA-FREE by
    /// design: mul/add/sqrt/div are correctly-rounded IEEE elementwise ops,
    /// so each lane is bitwise equal to the scalar `optim::adam_span` body
    /// — do not "optimize" this into `_mm256_fmadd_ps`, it would break the
    /// parallel/chunked bit-identity invariants.
    #[target_feature(enable = "avx2")]
    pub unsafe fn adam_span_avx2(
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        d: &mut [f32],
        k: AdamCoefs,
    ) -> usize {
        let n = g.len();
        let main = n - n % 8;
        let b1 = _mm256_set1_ps(k.beta1);
        let o1 = _mm256_set1_ps(k.om_b1);
        let b2 = _mm256_set1_ps(k.beta2);
        let o2 = _mm256_set1_ps(k.om_b2);
        let eps = _mm256_set1_ps(k.eps);
        let bc1 = _mm256_set1_ps(k.bc1);
        let bc2s = _mm256_set1_ps(k.bc2_sqrt);
        let gp = g.as_ptr();
        let mp = m.as_mut_ptr();
        let vp = v.as_mut_ptr();
        let dp = d.as_mut_ptr();
        let mut i = 0;
        while i < main {
            let gv = _mm256_loadu_ps(gp.add(i));
            // mval = b1*m + om1*g          (same op order as the scalar body)
            let mval =
                _mm256_add_ps(_mm256_mul_ps(b1, _mm256_loadu_ps(mp.add(i))), _mm256_mul_ps(o1, gv));
            // vval = b2*v + (om2*g)*g
            let vval = _mm256_add_ps(
                _mm256_mul_ps(b2, _mm256_loadu_ps(vp.add(i))),
                _mm256_mul_ps(_mm256_mul_ps(o2, gv), gv),
            );
            _mm256_storeu_ps(mp.add(i), mval);
            _mm256_storeu_ps(vp.add(i), vval);
            // d = (mval*bc1) / (sqrt(vval)*bc2_sqrt + eps)
            let den = _mm256_add_ps(_mm256_mul_ps(_mm256_sqrt_ps(vval), bc2s), eps);
            _mm256_storeu_ps(dp.add(i), _mm256_div_ps(_mm256_mul_ps(mval, bc1), den));
            i += 8;
        }
        main
    }

    /// SAFETY: caller checked AVX2.  Integer replica of
    /// `codec::bf16::f32_to_bf16_bits`: RNE via `bits + 0x7FFF + lsb`
    /// (wrapping add, exactly like the scalar `wrapping_add`), NaN lanes
    /// take `(bits >> 16) | 0x0040` instead.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_encode_avx2(src: &[f32], dst: &mut PooledBytes) -> usize {
        let n = src.len();
        let main = n - n % 8;
        let bias = _mm256_set1_epi32(0x7FFF);
        let one = _mm256_set1_epi32(1);
        let quiet = _mm256_set1_epi32(0x0040);
        let mut tmp = [0u8; 16];
        let mut i = 0;
        while i < main {
            let x = _mm256_loadu_ps(src.as_ptr().add(i));
            let bits = _mm256_castps_si256(x);
            // NaN mask: x unordered with itself (any NaN encoding).
            let nan = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_UNORD_Q>(x, x));
            let hi = _mm256_srli_epi32::<16>(bits);
            let lsb = _mm256_and_si256(hi, one);
            let rounded =
                _mm256_srli_epi32::<16>(_mm256_add_epi32(bits, _mm256_add_epi32(bias, lsb)));
            let nan_h = _mm256_or_si256(hi, quiet);
            let h = _mm256_blendv_epi8(rounded, nan_h, nan);
            // u32 lanes (all <= 0xFFFF, so packus never saturates) -> the
            // low 128 bits as 8 u16s; x86 is little-endian, so the stored
            // bytes equal the scalar `to_le_bytes` stream.
            let packed = _mm256_permute4x64_epi64::<0b00_00_10_00>(_mm256_packus_epi32(h, h));
            _mm_storeu_si128(tmp.as_mut_ptr().cast(), _mm256_castsi256_si128(packed));
            dst.extend_from_slice(&tmp);
            i += 8;
        }
        main
    }

    /// SAFETY: caller checked AVX2 and `src.len() == dst.len() * 2`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_decode_avx2(src: &[u8], dst: &mut [f32]) -> usize {
        let n = dst.len();
        let main = n - n % 8;
        let mut i = 0;
        while i < main {
            let h = _mm_loadu_si128(src.as_ptr().add(i * 2).cast());
            let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_castsi256_ps(w));
            i += 8;
        }
        main
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // NOTE: none of these tests calls set_force_scalar — the flag is
    // process-global and flipping it mid-suite would race the kernel
    // bit-identity tests.  Parity is checked by comparing the SIMD wrapper
    // output against an inline scalar replica instead; on machines without
    // AVX2 (or under LSP_FORCE_SCALAR=1) the wrappers report "not run" and
    // the assertions reduce to checking that contract.

    #[test]
    fn impl_name_matches_activity() {
        assert_eq!(active_impl_name(), if avx2_active() { "avx2" } else { "scalar" });
    }

    #[test]
    fn micro_nn_matches_scalar_tile() {
        let mut rng = Rng::new(11);
        let (lda, ldb, ldc, kb) = (23usize, 37usize, 19usize, 17usize);
        let a = rng.normal_vec((MR - 1) * lda + kb, 1.0);
        let b = rng.normal_vec((kb - 1) * ldb + NR, 1.0);
        for h in 1..=MR {
            let mut c_simd = rng.normal_vec((h - 1) * ldc + NR, 1.0);
            let mut c_ref = c_simd.clone();
            if !micro_nn(&a, lda, &b, ldb, &mut c_simd, ldc, kb, h) {
                assert!(!avx2_active(), "wrapper must run whenever SIMD is active");
                continue;
            }
            // Scalar replica of kernel::micro_nn_full restricted to h rows.
            let mut acc = [[0f32; NR]; MR];
            for l in 0..kb {
                for (i, lane) in acc.iter_mut().take(h).enumerate() {
                    let av = a[i * lda + l];
                    for (x, &bv) in lane.iter_mut().zip(&b[l * ldb..l * ldb + NR]) {
                        *x += av * bv;
                    }
                }
            }
            for i in 0..h {
                for (cv, &x) in c_ref[i * ldc..i * ldc + NR].iter_mut().zip(&acc[i]) {
                    *cv += x;
                }
            }
            for (i, (&s, &r)) in c_simd.iter().zip(&c_ref).enumerate() {
                let rel = (s - r).abs() / r.abs().max(1.0);
                assert!(rel < 1e-4, "h={h} elem {i}: simd {s} vs scalar {r}");
            }
        }
    }

    #[test]
    fn dot_matches_dot_lanes() {
        let mut rng = Rng::new(13);
        for n in [1usize, 7, 8, 16, 31, 64, 257] {
            let x = rng.normal_vec(n, 1.0);
            let y = rng.normal_vec(n, 1.0);
            let scalar = dot_lanes(&x, &y);
            let simd = dot(&x, &y);
            let tol = 1e-4 * scalar.abs().max(1.0);
            assert!((simd - scalar).abs() < tol, "n={n}: {simd} vs {scalar}");
        }
    }

    #[test]
    fn adam_prefix_bitwise_matches_scalar() {
        let mut rng = Rng::new(29);
        let n = 67; // 8 full lanes + tail
        let mut g = rng.normal_vec(n, 1.0);
        // Specials: zeros, signed zero, huge, tiny (denormal), NaN.
        g[0] = 0.0;
        g[1] = -0.0;
        g[2] = 3.0e37;
        g[3] = f32::from_bits(1); // smallest positive denormal
        g[4] = f32::NAN;
        let coefs = AdamCoefs {
            beta1: 0.9,
            om_b1: 1.0 - 0.9,
            beta2: 0.999,
            om_b2: 1.0 - 0.999,
            eps: 1e-8,
            bc1: 1.0 / (1.0 - 0.9f32),
            bc2_sqrt: (1.0 / (1.0 - 0.999f32)).sqrt(),
        };
        let m0 = rng.normal_vec(n, 0.1);
        let v0: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
        let (mut m_s, mut v_s) = (m0.clone(), v0.clone());
        let (mut m_x, mut v_x) = (m0, v0);
        let mut d_s = vec![0f32; n];
        let mut d_x = vec![0f32; n];
        let done = adam_span_prefix(&g, &mut m_x, &mut v_x, &mut d_x, coefs);
        assert!(done % 8 == 0 && done <= n);
        if avx2_active() {
            assert_eq!(done, n - n % 8, "active SIMD must cover the full prefix");
        } else {
            assert_eq!(done, 0);
        }
        // Scalar replica of the optim::adam_span body over the prefix.
        for i in 0..done {
            let gval = g[i];
            let mval = coefs.beta1 * m_s[i] + coefs.om_b1 * gval;
            let vval = coefs.beta2 * v_s[i] + coefs.om_b2 * gval * gval;
            m_s[i] = mval;
            v_s[i] = vval;
            d_s[i] = (mval * coefs.bc1) / (vval.sqrt() * coefs.bc2_sqrt + coefs.eps);
        }
        for i in 0..done {
            assert_eq!(m_s[i].to_bits(), m_x[i].to_bits(), "m[{i}]");
            assert_eq!(v_s[i].to_bits(), v_x[i].to_bits(), "v[{i}]");
            assert_eq!(d_s[i].to_bits(), d_x[i].to_bits(), "d[{i}]");
        }
    }
}
