//! Dense linear-algebra ops on host tensors.
//!
//! The `matmul` family is the host hot path for the GaLore/LoRA baselines,
//! the linalg substrate (QR / randomized SVD) and the projector manager.
//! Since the §Perf pass each entry point dispatches to the blocked,
//! register-tiled, multi-threaded kernels in `tensor::kernel` (worker width
//! and block sizes come from the process-wide `KernelConfig`, which the
//! coordinator negotiates against its own schedule-level threads).  The
//! original single-threaded triple loops survive as `matmul_*_ref` — the
//! oracles the property tests and `benches/hotpath.rs` compare against.

use anyhow::{bail, Result};

use super::kernel::{self, KernelConfig};
use super::Tensor;

fn mm_shapes(a: &Tensor, b: &Tensor) -> Result<(usize, usize, usize)> {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul shape mismatch: {:?} @ {:?}", a.shape(), b.shape());
    }
    Ok((m, k, n))
}

/// C = A @ B (blocked, multi-threaded).
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_with(a, b, &kernel::current())
}

/// C = A @ B with an explicit kernel configuration.
pub fn matmul_with(a: &Tensor, b: &Tensor, cfg: &KernelConfig) -> Result<Tensor> {
    let (m, k, n) = mm_shapes(a, b)?;
    let mut c = Tensor::zeros(&[m, n]);
    kernel::gemm_nn(a.data(), b.data(), c.data_mut(), m, k, n, cfg);
    Ok(c)
}

/// C = A^T @ B  (A: [k, m], B: [k, n] -> C: [m, n]) without materializing
/// A^T (blocked, multi-threaded).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_tn_with(a, b, &kernel::current())
}

pub fn matmul_tn_with(a: &Tensor, b: &Tensor, cfg: &KernelConfig) -> Result<Tensor> {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul_tn shape mismatch: {:?}^T @ {:?}", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    kernel::gemm_tn(a.data(), b.data(), c.data_mut(), k, m, n, cfg);
    Ok(c)
}

/// C = A @ B^T  (A: [m, k], B: [n, k] -> C: [m, n]) (blocked,
/// multi-threaded, lane-accumulated dot products).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_nt_with(a, b, &kernel::current())
}

pub fn matmul_nt_with(a: &Tensor, b: &Tensor, cfg: &KernelConfig) -> Result<Tensor> {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul_nt shape mismatch: {:?} @ {:?}^T", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    kernel::gemm_nt(a.data(), b.data(), c.data_mut(), m, k, n, cfg);
    Ok(c)
}

// ---- naive single-threaded references (oracles) -------------------------

/// Reference C = A @ B: ikj loop order, zero-skip, single-threaded.
pub fn matmul_ref(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k, n) = mm_shapes(a, b)?;
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i in 0..m {
        let crow = &mut cd[i * n..(i + 1) * n];
        for l in 0..k {
            let av = ad[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[l * n..(l + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Ok(c)
}

/// Reference C = A^T @ B.
pub fn matmul_tn_ref(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul_tn shape mismatch: {:?}^T @ {:?}", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for l in 0..k {
        let arow = &ad[l * m..(l + 1) * m];
        let brow = &bd[l * n..(l + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Ok(c)
}

/// Reference C = A @ B^T, dot-product form with zero-skip + row streaming:
/// all-zero A rows are skipped outright, and B rows are visited in blocks
/// small enough to stay cache-resident across consecutive A rows (the
/// original form re-streamed all of B per A row, which made the oracle
/// itself pathologically slow at bench shapes).  Zero-skip follows the
/// sibling oracles' convention (`0 * x` treated as 0), so like them it
/// diverges from the blocked kernels on non-finite inputs.
pub fn matmul_nt_ref(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul_nt shape mismatch: {:?} @ {:?}^T", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    // Zero-skip flags, computed once per A row (not once per B block).
    let zero_row: Vec<bool> = (0..m)
        .map(|i| ad[i * k..(i + 1) * k].iter().all(|&x| x == 0.0))
        .collect();
    // B-row block that fits in ~256 KiB.
    let jb = ((1usize << 16) / k.max(1)).clamp(8, 512);
    let mut j0 = 0;
    while j0 < n {
        let jend = (j0 + jb).min(n);
        for i in 0..m {
            if zero_row[i] {
                continue; // zero-skip: C row stays zero
            }
            let arow = &ad[i * k..(i + 1) * k];
            let crow = &mut cd[i * n..(i + 1) * n];
            for j in j0..jend {
                let brow = &bd[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                crow[j] = acc;
            }
        }
        j0 = jend;
    }
    Ok(c)
}

pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.set2(j, i, a.at2(i, j));
        }
    }
    t
}

/// y += alpha * x (elementwise, any matching shapes).
pub fn axpy(y: &mut Tensor, alpha: f32, x: &Tensor) {
    assert_eq!(y.shape(), x.shape());
    for (yv, xv) in y.data_mut().iter_mut().zip(x.data()) {
        *yv += alpha * xv;
    }
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Tensor::new(a.shape(), data).unwrap()
}

pub fn scale(a: &mut Tensor, s: f32) {
    for v in a.data_mut() {
        *v *= s;
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, close_rel_frob};
    use crate::util::rng::Rng;

    fn rand_mat(r: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::randn(&[m, n], 1.0, r)
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
        let cr = matmul_ref(&a, &b).unwrap();
        assert_eq!(cr.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_shape_mismatch_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul_ref(&a, &b).is_err());
        assert!(matmul_tn(&a, &b).is_err());
        assert!(matmul_nt(&a, &Tensor::zeros(&[4, 2])).is_err());
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(5);
        let a = rand_mat(&mut r, 7, 4);
        assert!(transpose(&transpose(&a)).allclose(&a, 0.0));
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        check(
            "tn/nt-vs-transpose",
            20,
            |r| {
                let (m, k, n) = (1 + r.below(12), 1 + r.below(12), 1 + r.below(12));
                (rand_mat(r, k, m), rand_mat(r, k, n), rand_mat(r, m, n))
            },
            |(a, b, c)| {
                let tn = matmul_tn(a, b).unwrap();
                let tn_ref = matmul(&transpose(a), b).unwrap();
                if !tn.allclose(&tn_ref, 1e-4) {
                    return Err("tn mismatch".into());
                }
                let nt = matmul_nt(b, c).unwrap();
                let nt_ref = matmul(b, &transpose(c)).unwrap();
                if !nt.allclose(&nt_ref, 1e-4) {
                    return Err("nt mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// The tentpole property: every blocked kernel matches its naive
    /// single-threaded oracle to within 1e-4 relative Frobenius error,
    /// across randomized shapes, thread counts and (deliberately awkward)
    /// block sizes that exercise all edge-tile paths.
    #[test]
    fn blocked_kernels_match_reference_property() {
        check(
            "blocked-vs-ref",
            24,
            |r| {
                let m = 1 + r.below(70);
                let k = 1 + r.below(70);
                let n = 1 + r.below(70);
                let cfg = KernelConfig {
                    threads: 1 + r.below(4),
                    block_m: 1 + r.below(24),
                    block_n: 1 + r.below(48),
                    block_k: 1 + r.below(48),
                    // 1 forces the packed path even at these small k, so
                    // the property pins packed, un-packed and disabled.
                    pack_min_k: [0, 1, 64][r.below(3)],
                };
                (
                    rand_mat(r, m, k), // A
                    rand_mat(r, k, n), // B
                    rand_mat(r, k, m), // A^T operand
                    rand_mat(r, n, k), // B^T operand
                    cfg,
                )
            },
            |(a, b, at, bt, cfg)| {
                close_rel_frob(
                    &matmul_with(a, b, cfg).map_err(|e| e.to_string())?,
                    &matmul_ref(a, b).map_err(|e| e.to_string())?,
                    1e-4,
                )
                .map_err(|e| format!("nn: {e}"))?;
                close_rel_frob(
                    &matmul_tn_with(at, b, cfg).map_err(|e| e.to_string())?,
                    &matmul_tn_ref(at, b).map_err(|e| e.to_string())?,
                    1e-4,
                )
                .map_err(|e| format!("tn: {e}"))?;
                close_rel_frob(
                    &matmul_nt_with(a, bt, cfg).map_err(|e| e.to_string())?,
                    &matmul_nt_ref(a, bt).map_err(|e| e.to_string())?,
                    1e-4,
                )
                .map_err(|e| format!("nt: {e}"))?;
                Ok(())
            },
        );
    }

    #[test]
    fn nt_ref_zero_skip_keeps_exact_semantics() {
        // Rows of zeros must yield rows of zeros, and a mixed matrix must
        // match the blocked kernel.
        let mut r = Rng::new(33);
        let mut a = rand_mat(&mut r, 9, 21);
        for v in a.data_mut()[2 * 21..3 * 21].iter_mut() {
            *v = 0.0;
        }
        let b = rand_mat(&mut r, 13, 21);
        let fast = matmul_nt(&a, &b).unwrap();
        let slow = matmul_nt_ref(&a, &b).unwrap();
        assert!(close_rel_frob(&fast, &slow, 1e-4).is_ok());
        for j in 0..13 {
            assert_eq!(slow.at2(2, j), 0.0, "zero-skipped row stays zero");
        }
    }

    #[test]
    fn nt_ref_zero_skip_nan_denormal_audit() {
        // Satellite audit for the SIMD refactor: the oracle's zero-skip
        // divergence on non-finite inputs and its exact-zero test must hold
        // under both the scalar and the FMA dot path (see the tolerance
        // contract in `tensor/kernel.rs` module docs).
        let mut r = Rng::new(51);
        let (k, n) = (21, 9);
        let mut a = rand_mat(&mut r, 4, k);
        for v in a.data_mut()[0..k].iter_mut() {
            *v = 0.0; // row 0: exact zeros -> skipped by the oracle
        }
        let denormal = f32::from_bits(1000); // ~1.4e-42, subnormal
        for v in a.data_mut()[k..2 * k].iter_mut() {
            *v = denormal; // row 1: subnormal, must NOT be skipped
        }
        let mut b = rand_mat(&mut r, n, k);
        b.data_mut()[0] = f32::NAN; // B row 0, element 0
        let fast = matmul_nt(&a, &b).unwrap();
        let slow = matmul_nt_ref(&a, &b).unwrap();
        // Zero-skip: the oracle never reads B for an all-zero A row, so the
        // NaN cannot propagate there — the documented divergence.
        for j in 0..n {
            assert_eq!(slow.at2(0, j), 0.0, "oracle zero-skip row");
        }
        // The kernel computes 0.0 * NaN = NaN (mul+add and FMA agree).
        assert!(fast.at2(0, 0).is_nan(), "kernel propagates NaN");
        // Subnormal rows are computed by both paths (the skip tests exact
        // zero, not "tiny").  NaN still propagates through both dot forms;
        // finite products ~1e-42 are representable subnormals, where FMA's
        // fused rounding and scalar mul+add agree to well under 1e-38.
        assert!(slow.at2(1, 0).is_nan() && fast.at2(1, 0).is_nan());
        for j in 1..n {
            let (f, s) = (fast.at2(1, j), slow.at2(1, j));
            assert!(f.is_finite() && s.is_finite(), "j={j}");
            assert!((f - s).abs() < 1e-38, "j={j}: {f} vs {s}");
        }
    }

    #[test]
    fn matmul_associativity_property() {
        check(
            "matmul-assoc",
            10,
            |r| {
                let (m, k, l, n) =
                    (1 + r.below(8), 1 + r.below(8), 1 + r.below(8), 1 + r.below(8));
                (rand_mat(r, m, k), rand_mat(r, k, l), rand_mat(r, l, n))
            },
            |(a, b, c)| {
                let left = matmul(&matmul(a, b).unwrap(), c).unwrap();
                let right = matmul(a, &matmul(b, c).unwrap()).unwrap();
                if left.allclose(&right, 1e-3) {
                    Ok(())
                } else {
                    Err(format!("assoc diff {}", left.max_abs_diff(&right)))
                }
            },
        );
    }

    #[test]
    fn axpy_and_sub() {
        let mut y = Tensor::new(&[1, 3], vec![1., 2., 3.]).unwrap();
        let x = Tensor::new(&[1, 3], vec![1., 1., 1.]).unwrap();
        axpy(&mut y, 2.0, &x);
        assert_eq!(y.data(), &[3., 4., 5.]);
        let d = sub(&y, &x);
        assert_eq!(d.data(), &[2., 3., 4.]);
    }
}
