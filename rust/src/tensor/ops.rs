//! Dense linear-algebra ops on host tensors.
//!
//! `matmul` is the host hot path for the GaLore/LoRA baselines and the
//! projector manager; it uses an ikj loop order (stream rows of B against an
//! accumulator row of C) which vectorizes well and is cache-friendly for
//! row-major data.  All ops are single-threaded by design — the coordinator
//! dedicates its worker threads at the schedule level, not inside kernels.

use anyhow::{bail, Result};

use super::Tensor;

/// C = A @ B.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul shape mismatch: {:?} @ {:?}", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i in 0..m {
        let crow = &mut cd[i * n..(i + 1) * n];
        for l in 0..k {
            let av = ad[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[l * n..(l + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Ok(c)
}

/// C = A^T @ B  (A: [k, m], B: [k, n] -> C: [m, n]) without materializing A^T.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul_tn shape mismatch: {:?}^T @ {:?}", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for l in 0..k {
        let arow = &ad[l * m..(l + 1) * m];
        let brow = &bd[l * n..(l + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Ok(c)
}

/// C = A @ B^T  (A: [m, k], B: [n, k] -> C: [m, n]); dot-product form.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    if k != k2 {
        bail!("matmul_nt shape mismatch: {:?} @ {:?}^T", a.shape(), b.shape());
    }
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            cd[i * n + j] = acc;
        }
    }
    Ok(c)
}

pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.set2(j, i, a.at2(i, j));
        }
    }
    t
}

/// y += alpha * x (elementwise, any matching shapes).
pub fn axpy(y: &mut Tensor, alpha: f32, x: &Tensor) {
    assert_eq!(y.shape(), x.shape());
    for (yv, xv) in y.data_mut().iter_mut().zip(x.data()) {
        *yv += alpha * xv;
    }
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Tensor::new(a.shape(), data).unwrap()
}

pub fn scale(a: &mut Tensor, s: f32) {
    for v in a.data_mut() {
        *v *= s;
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn rand_mat(r: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::randn(&[m, n], 1.0, r)
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_shape_mismatch_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(5);
        let a = rand_mat(&mut r, 7, 4);
        assert!(transpose(&transpose(&a)).allclose(&a, 0.0));
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        check(
            "tn/nt-vs-transpose",
            20,
            |r| {
                let (m, k, n) = (1 + r.below(12), 1 + r.below(12), 1 + r.below(12));
                (rand_mat(r, k, m), rand_mat(r, k, n), rand_mat(r, m, n))
            },
            |(a, b, c)| {
                let tn = matmul_tn(a, b).unwrap();
                let tn_ref = matmul(&transpose(a), b).unwrap();
                if !tn.allclose(&tn_ref, 1e-4) {
                    return Err("tn mismatch".into());
                }
                let nt = matmul_nt(b, c).unwrap();
                let nt_ref = matmul(b, &transpose(c)).unwrap();
                if !nt.allclose(&nt_ref, 1e-4) {
                    return Err("nt mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matmul_associativity_property() {
        check(
            "matmul-assoc",
            10,
            |r| {
                let (m, k, l, n) =
                    (1 + r.below(8), 1 + r.below(8), 1 + r.below(8), 1 + r.below(8));
                (rand_mat(r, m, k), rand_mat(r, k, l), rand_mat(r, l, n))
            },
            |(a, b, c)| {
                let left = matmul(&matmul(a, b).unwrap(), c).unwrap();
                let right = matmul(a, &matmul(b, c).unwrap()).unwrap();
                if left.allclose(&right, 1e-3) {
                    Ok(())
                } else {
                    Err(format!("assoc diff {}", left.max_abs_diff(&right)))
                }
            },
        );
    }

    #[test]
    fn axpy_and_sub() {
        let mut y = Tensor::new(&[1, 3], vec![1., 2., 3.]).unwrap();
        let x = Tensor::new(&[1, 3], vec![1., 1., 1.]).unwrap();
        axpy(&mut y, 2.0, &x);
        assert_eq!(y.data(), &[3., 4., 5.]);
        let d = sub(&y, &x);
        assert_eq!(d.data(), &[2., 3., 4.]);
    }
}
