//! `Int8Block` — Endor-style block absmax quantization: the payload is cut
//! into blocks of `block` elements; each block ships one f32 scale
//! (`absmax / 127`) followed by one signed byte per element
//! (`round(x / scale)`).  Wire cost: `n + 4 * ceil(n / block)` bytes.
//!
//! Error: per element `|x - q*scale| <= scale/2 = absmax/254`, so the
//! relative L2 error of a block is at most `sqrt(block)/254` (the block's
//! norm is at least its absmax), and blocks partition the payload, so the
//! same bound holds for the whole vector.  Declared with a little headroom
//! for the f32 arithmetic in quantize/dequantize.  Non-finite inputs
//! degrade gracefully: a block whose absmax is not finite is flushed to
//! zeros rather than poisoning the scale.

use anyhow::{bail, Result};

use super::{ByteBuf, Codec};

/// Stack-buffer limit for block-streaming encoders (`SparseIdx` gathers
/// non-zeros into a `[f32; MAX_BLOCK]` before flushing).
pub(crate) const MAX_BLOCK: usize = 256;

/// Append one quantized block: f32 scale, then `vals.len()` signed bytes.
pub(crate) fn encode_block(vals: &[f32], dst: &mut ByteBuf) {
    let absmax = vals.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let scale = absmax / 127.0;
    if scale == 0.0 || !scale.is_finite() {
        dst.extend_from_slice(&0.0f32.to_le_bytes());
        for _ in vals {
            dst.push(0);
        }
        return;
    }
    dst.extend_from_slice(&scale.to_le_bytes());
    for &x in vals {
        let q = (x / scale).round().clamp(-127.0, 127.0);
        // A NaN element casts to 0 — lossy by design.
        dst.push(q as i8 as u8);
    }
}

/// Decode one block (`src` = 4 scale bytes + `out.len()` value bytes).
pub(crate) fn decode_block(src: &[u8], out: &mut [f32]) -> Result<()> {
    if src.len() != 4 + out.len() {
        bail!("int8 block is {} bytes, want {}", src.len(), 4 + out.len());
    }
    let scale = f32::from_le_bytes(src[..4].try_into().unwrap());
    for (o, &b) in out.iter_mut().zip(&src[4..]) {
        *o = (b as i8) as f32 * scale;
    }
    Ok(())
}

#[derive(Debug, Clone, Copy)]
pub struct Int8Block {
    pub block: usize,
}

impl Int8Block {
    pub fn new(block: usize) -> Int8Block {
        assert!(
            (1..=MAX_BLOCK).contains(&block),
            "int8 block size must be in 1..={MAX_BLOCK}, got {block}"
        );
        Int8Block { block }
    }
}

impl Codec for Int8Block {
    fn name(&self) -> String {
        format!("int8-{}", self.block)
    }

    fn encode(&self, src: &[f32], dst: &mut ByteBuf) {
        dst.reserve(self.wire_len(src));
        for chunk in src.chunks(self.block) {
            encode_block(chunk, dst);
        }
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) -> Result<()> {
        if src.len() != self.wire_len_for(dst.len()) {
            bail!(
                "int8-{} payload is {} bytes, want {} for {} elems",
                self.block,
                src.len(),
                self.wire_len_for(dst.len()),
                dst.len()
            );
        }
        let mut pos = 0;
        for chunk in dst.chunks_mut(self.block) {
            let take = 4 + chunk.len();
            decode_block(&src[pos..pos + take], chunk)?;
            pos += take;
        }
        Ok(())
    }

    fn wire_len(&self, src: &[f32]) -> usize {
        self.wire_len_for(src.len())
    }

    fn rel_l2_bound(&self) -> f32 {
        // Mathematical bound sqrt(block)/254 (see module docs), declared as
        // sqrt(block)/240 to absorb f32 rounding in the two conversions.
        (self.block as f32).sqrt() / 240.0
    }
}

impl Int8Block {
    fn wire_len_for(&self, n: usize) -> usize {
        n + 4 * n.div_ceil(self.block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_for_grid_values() {
        // Values on the quantization grid round-trip exactly: each block's
        // absmax is 127 * 2^k (scale = 2^k, exactly representable) and every
        // value is an integer multiple of the scale.
        let c = Int8Block::new(4);
        let data = [127.0f32, -127.0, 64.0, 0.0, 254.0, -2.0, 64.0, 2.0];
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(&data, &mut buf);
        assert_eq!(buf.len(), c.wire_len(&data));
        let mut out = [0f32; 8];
        c.decode(&buf, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn zero_and_nonfinite_blocks_flush_to_zero() {
        let c = Int8Block::new(4);
        let data = [0.0f32, 0.0, 0.0, 0.0, f32::INFINITY, 1.0, f32::NAN, -1.0];
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(&data, &mut buf);
        let mut out = [9f32; 8];
        c.decode(&buf, &mut out).unwrap();
        assert_eq!(&out[..4], &[0.0; 4]);
        assert_eq!(&out[4..], &[0.0; 4], "non-finite absmax flushes its block");
    }

    #[test]
    fn block_size_is_validated() {
        let r = std::panic::catch_unwind(|| Int8Block::new(0));
        assert!(r.is_err());
        let r = std::panic::catch_unwind(|| Int8Block::new(MAX_BLOCK + 1));
        assert!(r.is_err());
    }
}
