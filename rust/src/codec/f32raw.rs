//! `F32Raw` — the identity wire format: 4 bytes per element, little-endian
//! IEEE-754 bits.  Bit-exact round-trip (including NaN payloads and signed
//! zeros), and exactly the `4 * n` bytes the links charged before the codec
//! subsystem existed — this is the parity path every lossy codec is judged
//! against.

use anyhow::{bail, Result};

use super::{ByteBuf, Codec};

#[derive(Debug, Clone, Copy, Default)]
pub struct F32Raw;

impl Codec for F32Raw {
    fn name(&self) -> String {
        "f32".to_string()
    }

    fn encode(&self, src: &[f32], dst: &mut ByteBuf) {
        dst.reserve(src.len() * 4);
        for &x in src {
            dst.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) -> Result<()> {
        if src.len() != dst.len() * 4 {
            bail!("f32 payload is {} bytes, want {} for {} elems", src.len(), dst.len() * 4, dst.len());
        }
        for (out, b) in dst.iter_mut().zip(src.chunks_exact(4)) {
            *out = f32::from_le_bytes(b.try_into().unwrap());
        }
        Ok(())
    }

    fn wire_len(&self, src: &[f32]) -> usize {
        src.len() * 4
    }

    fn rel_l2_bound(&self) -> f32 {
        0.0
    }
}
