//! The wire-format subsystem: how link payloads are encoded on the
//! emulated PCIe links.
//!
//! # Layering
//!
//! The paper's thesis is that commodity fine-tuning is communication-bound;
//! LSP shrinks *what* crosses the link (d x d subspace gradients instead of
//! m x n full gradients).  This subsystem makes *how* it crosses the link a
//! first-class, per-policy lever: every `OffloadMsg`/`DeltaMsg` payload is
//! encoded by a `Codec` before entering a link queue and decoded after
//! leaving it, the links charge the emulated bandwidth with the *encoded*
//! byte count, and `TrainReport` carries both wire bytes and the
//! f32-equivalent so the compression ratio is always visible.
//!
//! * **Trait** (`Codec`): `encode` appends the wire form of an f32 slice to
//!   a `ByteBuf` (a pooled byte buffer — see `util::bufpool::PooledBytes`),
//!   `decode` reconstructs exactly `dst.len()` elements, `wire_len` predicts
//!   the encoded size without encoding (links and pools size from it), and
//!   `rel_l2_bound` declares the worst-case relative L2 round-trip error
//!   (0.0 = lossless) that the property tests hold every implementation to.
//! * **Implementations**: `F32Raw` (4 B/elem, bit-exact — the oracle and
//!   the parity path), `Bf16` (2 B/elem, round-to-nearest-even truncation),
//!   `Int8Block` (1 B/elem + one f32 absmax scale per block, Endor-style
//!   block quantization), and `SparseIdx` (bitmap or delta-varint index
//!   coding of the non-zero positions, values in a configurable
//!   `ValueFormat` — `sparse-int8` is the LSP default, compact indices over
//!   block-quantized values).
//! * **Selection**: `TrainConfig::link_codec` (`--link-codec`, JSON
//!   `link_codec`) overrides; `None` defers to the policy's
//!   `UpdatePolicy::preferred_codec` (LSP -> `sparse-int8`, Zero -> `bf16`).
//!   `PipelineCtx::new` resolves the choice once and shares the `Arc<dyn
//!   Codec>` with the CPU updater thread, so both link endpoints always
//!   agree on the format.
//!
//! # Adding a codec
//!
//! Implement `Codec` in `codec/<name>.rs`, add a `CodecKind` variant with
//! `by_name`/`name`/`est_bytes_per_elem` arms and a `make_codec` arm.  Keep
//! `wire_len` exact (`codec_wire_len_matches_encode` pins it), declare an
//! honest `rel_l2_bound` (the round-trip property tests enforce it on
//! randomized payloads), and keep `encode`/`decode` allocation-free — all
//! scratch must be stack-resident or come from the caller's buffers, so the
//! steady-state pool tests stay true.  See ROADMAP.md §Codec for the
//! accuracy-vs-bytes guidance.

use std::sync::Arc;

use anyhow::{bail, Result};

pub mod bf16;
pub mod f32raw;
pub mod int8block;
pub mod sparseidx;

pub use bf16::Bf16;
pub use f32raw::F32Raw;
pub use int8block::Int8Block;
pub use sparseidx::{SparseIdx, ValueFormat};

/// The byte buffer codecs encode into: a pooled `Vec<u8>` so the encode /
/// decode hot path allocates nothing in steady state.
pub type ByteBuf = crate::util::bufpool::PooledBytes;

/// Default quantization block for the int8 codecs (one f32 absmax scale per
/// `block` elements; 64 keeps the scale overhead at 6% and the worst-case
/// per-block error bound at sqrt(64)/254 ~ 3.1%).
pub const DEFAULT_INT8_BLOCK: usize = 64;

/// One wire format for f32 link payloads.
///
/// Contract: `decode(encode(x))` reconstructs `x` within `rel_l2_bound()`
/// relative L2 error (bit-exact when the bound is 0.0), `encode` appends
/// exactly `wire_len(x)` bytes, and both directions are deterministic —
/// the two link endpoints run on different threads and must agree
/// byte-for-byte.
pub trait Codec: Send + Sync + std::fmt::Debug {
    /// Stable identifier (config value, report row, bench row).
    fn name(&self) -> String;

    /// Append the wire form of `src` to `dst`.
    fn encode(&self, src: &[f32], dst: &mut ByteBuf);

    /// Reconstruct exactly `dst.len()` elements from `src` (every element
    /// of `dst` is overwritten).  Fails on length/format mismatch.
    fn decode(&self, src: &[u8], dst: &mut [f32]) -> Result<()>;

    /// Exact number of bytes `encode(src)` would append (data-dependent for
    /// the sparse codecs).
    fn wire_len(&self, src: &[f32]) -> usize;

    /// Declared worst-case relative L2 round-trip error for normal-range
    /// inputs; 0.0 = lossless.
    fn rel_l2_bound(&self) -> f32;
}

/// The codec registry: every wire format the config system can name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// 4 B/elem, bit-exact (pre-codec behavior; the parity path).
    F32Raw,
    /// 2 B/elem, round-to-nearest-even bf16 truncation.
    Bf16,
    /// 1 B/elem + 4 B absmax scale per `DEFAULT_INT8_BLOCK` elements.
    Int8Block,
    /// Non-zero index coding (bitmap / delta-varint), f32 values — exact.
    SparseIdx,
    /// Non-zero index coding over int8-block-quantized values (LSP default).
    SparseInt8,
}

impl CodecKind {
    pub const ALL: [CodecKind; 5] = [
        CodecKind::F32Raw,
        CodecKind::Bf16,
        CodecKind::Int8Block,
        CodecKind::SparseIdx,
        CodecKind::SparseInt8,
    ];

    pub fn by_name(s: &str) -> Option<CodecKind> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "f32raw" | "raw" => Some(CodecKind::F32Raw),
            "bf16" => Some(CodecKind::Bf16),
            "int8" | "int8block" | "int8-block" => Some(CodecKind::Int8Block),
            "sparse" | "sparseidx" | "sparse-f32" => Some(CodecKind::SparseIdx),
            "sparse-int8" | "sparse+int8" | "sparseint8" => Some(CodecKind::SparseInt8),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecKind::F32Raw => "f32",
            CodecKind::Bf16 => "bf16",
            CodecKind::Int8Block => "int8",
            CodecKind::SparseIdx => "sparse-f32",
            CodecKind::SparseInt8 => "sparse-int8",
        }
    }

    /// Analytic wire bytes per element for a payload whose fraction of
    /// non-zero elements is `nonzero_frac` — the cost-model's view of the
    /// codec (sparse estimates assume bitmap index mode and ignore the
    /// constant header).
    pub fn est_bytes_per_elem(&self, nonzero_frac: f64) -> f64 {
        let scale_overhead = 4.0 / DEFAULT_INT8_BLOCK as f64;
        match self {
            CodecKind::F32Raw => 4.0,
            CodecKind::Bf16 => 2.0,
            CodecKind::Int8Block => 1.0 + scale_overhead,
            CodecKind::SparseIdx => 0.125 + 4.0 * nonzero_frac,
            CodecKind::SparseInt8 => 0.125 + (1.0 + scale_overhead) * nonzero_frac,
        }
    }

    /// Stable one-byte wire identifier for per-entry codec tagging: the
    /// KV-cache stamps every spilled entry with the codec that encoded it,
    /// so a restore decodes with exactly that codec even if the session's
    /// negotiated codec changed in between.  Distinct namespace from
    /// `fault::CODEC_TAG_*`, which tags chunk *negotiation state* on the
    /// link protocol, not codec identity.
    pub fn wire_tag(&self) -> u8 {
        match self {
            CodecKind::F32Raw => 0,
            CodecKind::Bf16 => 1,
            CodecKind::Int8Block => 2,
            CodecKind::SparseIdx => 3,
            CodecKind::SparseInt8 => 4,
        }
    }

    /// Inverse of [`CodecKind::wire_tag`]; `None` for unknown tags (a
    /// corrupt or future-format entry — callers surface a decode error).
    pub fn from_wire_tag(tag: u8) -> Option<CodecKind> {
        CodecKind::ALL.iter().copied().find(|k| k.wire_tag() == tag)
    }
}

/// Construct the codec object for `kind` — the only codec dispatch;
/// everything downstream goes through the trait.
pub fn make_codec(kind: CodecKind) -> Arc<dyn Codec> {
    match kind {
        CodecKind::F32Raw => Arc::new(F32Raw),
        CodecKind::Bf16 => Arc::new(Bf16),
        CodecKind::Int8Block => Arc::new(Int8Block::new(DEFAULT_INT8_BLOCK)),
        CodecKind::SparseIdx => Arc::new(SparseIdx::new(ValueFormat::F32)),
        CodecKind::SparseInt8 => {
            Arc::new(SparseIdx::new(ValueFormat::Int8 { block: DEFAULT_INT8_BLOCK }))
        }
    }
}

// ---- LEB128 varint helpers (shared by the sparse index coder) -----------

pub(crate) fn varint_len(mut v: u32) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

pub(crate) fn push_varint(dst: &mut ByteBuf, mut v: u32) {
    while v >= 0x80 {
        dst.push((v as u8) | 0x80);
        v >>= 7;
    }
    dst.push(v as u8);
}

pub(crate) fn read_varint(src: &[u8], pos: &mut usize) -> Result<u32> {
    let mut out = 0u32;
    let mut shift = 0u32;
    loop {
        let Some(&b) = src.get(*pos) else {
            bail!("varint runs past the end of the payload");
        };
        *pos += 1;
        out |= ((b & 0x7f) as u32) << shift;
        if b & 0x80 == 0 {
            return Ok(out);
        }
        shift += 7;
        if shift >= 35 {
            bail!("varint longer than 5 bytes");
        }
    }
}

pub(crate) fn read_u32(src: &[u8], pos: &mut usize) -> Result<u32> {
    let Some(b) = src.get(*pos..*pos + 4) else {
        bail!("u32 runs past the end of the payload");
    };
    *pos += 4;
    Ok(u32::from_le_bytes(b.try_into().unwrap()))
}

pub(crate) fn read_f32(src: &[u8], pos: &mut usize) -> Result<f32> {
    Ok(f32::from_bits(read_u32(src, pos)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_payload(r: &mut Rng) -> Vec<f32> {
        let n = r.below(400);
        let zero_frac = r.f32();
        (0..n)
            .map(|_| if r.f32() < zero_frac { 0.0 } else { r.normal() })
            .collect()
    }

    fn encode_detached(c: &dyn Codec, src: &[f32]) -> Vec<u8> {
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(src, &mut buf);
        buf.into_vec()
    }

    #[test]
    fn registry_round_trips_names() {
        for kind in CodecKind::ALL {
            assert_eq!(CodecKind::by_name(kind.name()), Some(kind), "{kind:?}");
            // The object name may carry parameters ("int8-64"), but always
            // extends the registry name.
            let codec = make_codec(kind);
            assert!(
                codec.name().starts_with(kind.name()),
                "codec {:?} vs kind {:?}",
                codec.name(),
                kind.name()
            );
        }
        assert_eq!(CodecKind::by_name("bogus"), None);
        assert_eq!(CodecKind::by_name("BF16"), Some(CodecKind::Bf16));
    }

    #[test]
    fn wire_tags_round_trip_and_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for kind in CodecKind::ALL {
            let tag = kind.wire_tag();
            assert!(seen.insert(tag), "duplicate wire tag {tag} for {kind:?}");
            assert_eq!(CodecKind::from_wire_tag(tag), Some(kind));
        }
        assert_eq!(CodecKind::from_wire_tag(0xff), None);
    }

    #[test]
    fn est_bytes_per_elem_orders_sensibly() {
        // Dense payloads: f32 > bf16 > int8; sparse estimates shrink with
        // density and beat the dense encodings below ~25% non-zeros.
        assert_eq!(CodecKind::F32Raw.est_bytes_per_elem(1.0), 4.0);
        assert_eq!(CodecKind::Bf16.est_bytes_per_elem(1.0), 2.0);
        let int8 = CodecKind::Int8Block.est_bytes_per_elem(1.0);
        assert!(int8 > 1.0 && int8 < 1.2, "{int8}");
        let sp_dense = CodecKind::SparseInt8.est_bytes_per_elem(1.0);
        assert!(sp_dense < 2.0, "dense sparse-int8 still beats bf16: {sp_dense}");
        let sp_10 = CodecKind::SparseIdx.est_bytes_per_elem(0.1);
        assert!(sp_10 < 1.0, "10%-dense sparse-f32: {sp_10}");
    }

    /// Every codec: `wire_len` predicts the encoded size exactly, and
    /// `decode` reconstructs within the declared relative-L2 bound.
    #[test]
    fn codec_wire_len_matches_encode_and_bound_holds() {
        check(
            "codec-wire-roundtrip",
            24,
            |r| {
                let kind = CodecKind::ALL[r.below(CodecKind::ALL.len())];
                (kind, random_payload(r))
            },
            |(kind, data)| {
                let c = make_codec(*kind);
                let wire = encode_detached(c.as_ref(), data);
                if wire.len() != c.wire_len(data) {
                    return Err(format!(
                        "{}: wire_len {} != encoded {}",
                        c.name(),
                        c.wire_len(data),
                        wire.len()
                    ));
                }
                let mut out = vec![f32::NAN; data.len()];
                c.decode(&wire, &mut out).map_err(|e| e.to_string())?;
                let (mut err2, mut ref2) = (0f64, 0f64);
                for (&a, &b) in data.iter().zip(&out) {
                    err2 += ((a - b) as f64).powi(2);
                    ref2 += (a as f64).powi(2);
                }
                let rel = if ref2 == 0.0 { err2.sqrt() } else { (err2 / ref2).sqrt() };
                if rel > c.rel_l2_bound() as f64 {
                    return Err(format!(
                        "{}: rel L2 {rel} > declared bound {}",
                        c.name(),
                        c.rel_l2_bound()
                    ));
                }
                Ok(())
            },
        );
    }

    /// Lossless codecs: value-exact round-trip (F32Raw additionally
    /// bit-exact; SparseIdx canonicalizes -0.0 to +0.0).
    #[test]
    fn lossless_codecs_round_trip_exactly() {
        check(
            "codec-lossless-roundtrip",
            16,
            |r| {
                let kind = if r.below(2) == 0 { CodecKind::F32Raw } else { CodecKind::SparseIdx };
                (kind, random_payload(r))
            },
            |(kind, data)| {
                let c = make_codec(*kind);
                assert_eq!(c.rel_l2_bound(), 0.0, "{} claims lossless", c.name());
                let wire = encode_detached(c.as_ref(), data);
                let mut out = vec![f32::NAN; data.len()];
                c.decode(&wire, &mut out).map_err(|e| e.to_string())?;
                for (i, (&a, &b)) in data.iter().zip(&out).enumerate() {
                    if a != b {
                        return Err(format!("{}: elem {i}: {a} != {b}", c.name()));
                    }
                }
                if *kind == CodecKind::F32Raw {
                    for (&a, &b) in data.iter().zip(&out) {
                        if a.to_bits() != b.to_bits() {
                            return Err("f32raw must be bit-exact".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decode_rejects_wrong_lengths() {
        for kind in CodecKind::ALL {
            let c = make_codec(kind);
            let data = [1.0f32, -2.0, 0.0, 3.5];
            let wire = encode_detached(c.as_ref(), &data);
            let mut short = vec![0f32; 3];
            assert!(c.decode(&wire, &mut short).is_err(), "{}: wrong dst len", c.name());
            if !wire.is_empty() {
                let mut out = vec![0f32; 4];
                assert!(
                    c.decode(&wire[..wire.len() - 1], &mut out).is_err(),
                    "{}: truncated wire",
                    c.name()
                );
            }
        }
    }

    #[test]
    fn varint_round_trips() {
        let mut buf = ByteBuf::detached(Vec::new());
        let vals = [0u32, 1, 127, 128, 300, 16_383, 16_384, u32::MAX];
        for &v in &vals {
            assert_eq!(varint_len(v), {
                let before = buf.len();
                push_varint(&mut buf, v);
                buf.len() - before
            });
        }
        let bytes = buf.into_vec();
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_varint(&bytes, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, bytes.len());
        assert!(read_varint(&bytes, &mut pos).is_err(), "past the end");
    }

    #[test]
    fn empty_payloads_are_fine() {
        for kind in CodecKind::ALL {
            let c = make_codec(kind);
            let wire = encode_detached(c.as_ref(), &[]);
            assert_eq!(wire.len(), c.wire_len(&[]));
            let mut out: Vec<f32> = vec![];
            c.decode(&wire, &mut out).unwrap();
        }
    }
}
