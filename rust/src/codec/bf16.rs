//! `Bf16` — 2 bytes per element: the upper half of the IEEE-754 f32 bit
//! pattern, rounded to nearest-even.  bf16 keeps f32's exponent range (no
//! overflow/underflow on conversion), so the only loss is the mantissa
//! truncation: relative error <= 2^-9 per element for normal-range inputs,
//! declared with headroom as 1/256.

use anyhow::{bail, Result};

use super::{ByteBuf, Codec};

/// f32 -> bf16 bits with round-to-nearest-even (the rounding the paper's
/// mixed-precision training stacks use).
pub(crate) fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep NaN a NaN: force a mantissa bit so truncation cannot
        // produce an infinity.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + lsb) >> 16) as u16
}

pub(crate) fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Bf16;

impl Codec for Bf16 {
    fn name(&self) -> String {
        "bf16".to_string()
    }

    fn encode(&self, src: &[f32], dst: &mut ByteBuf) {
        dst.reserve(src.len() * 2);
        // AVX2 prefix (bit-exact integer replica of f32_to_bf16_bits — see
        // tensor::simd), scalar loop on the tail / fallback machines.
        let done = crate::tensor::simd::bf16_encode_prefix(src, dst);
        for &x in &src[done..] {
            dst.extend_from_slice(&f32_to_bf16_bits(x).to_le_bytes());
        }
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) -> Result<()> {
        if src.len() != dst.len() * 2 {
            bail!("bf16 payload is {} bytes, want {} for {} elems", src.len(), dst.len() * 2, dst.len());
        }
        let done = crate::tensor::simd::bf16_decode_prefix(src, dst);
        for (out, b) in dst[done..].iter_mut().zip(src[done * 2..].chunks_exact(2)) {
            *out = bf16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
        }
        Ok(())
    }

    fn wire_len(&self, src: &[f32]) -> usize {
        src.len() * 2
    }

    fn rel_l2_bound(&self) -> f32 {
        // RNE truncation to 8 significand bits: per-element relative error
        // <= 2^-9/(1 - 2^-9); 2^-8 declared for headroom.
        1.0 / 256.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversion_basics() {
        // Values exactly representable in bf16 (<= 8 significand bits)
        // survive unchanged.
        for x in [0.0f32, 1.0, -2.0, 0.5, -0.09375, 1.5e1, f32::from_bits(0x7F00_0000)] {
            let y = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert_eq!(x, y, "{x} not preserved");
        }
        // Signs survive; NaN stays NaN; infinities stay infinite.
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(-1.5)).is_sign_negative());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 sits exactly between bf16(1.0) and the next bf16 up
        // (1 + 2^-7); RNE picks the even mantissa (1.0).  One f32 ulp above
        // the midpoint must round up.
        let midpoint = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(midpoint)), 1.0);
        let above = f32::from_bits(0x3F80_8001);
        let up = bf16_bits_to_f32(f32_to_bf16_bits(above));
        assert!(up > 1.0, "{above} must round up, got {up}");
    }

    #[test]
    fn simd_wire_bit_identical_to_scalar() {
        // The SIMD encode/decode prefixes must produce byte-identical
        // wires and bit-identical decodes vs. the pure scalar loops, over
        // random bit patterns and every special class.  On non-AVX2
        // machines (or LSP_FORCE_SCALAR=1) both sides run the scalar loop.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for n in [1usize, 7, 8, 9, 40, 129] {
            let mut src: Vec<f32> =
                (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
            src[0] = f32::NAN;
            if n > 4 {
                src[1] = -0.0;
                src[2] = f32::INFINITY;
                src[3] = f32::NEG_INFINITY;
                src[4] = f32::from_bits(1); // subnormal
            }
            // Scalar-only wire.
            let mut scalar_wire = Vec::with_capacity(n * 2);
            for &x in &src {
                scalar_wire.extend_from_slice(&f32_to_bf16_bits(x).to_le_bytes());
            }
            // Codec wire (SIMD prefix + scalar tail).
            let mut wire = ByteBuf::detached(Vec::new());
            Bf16.encode(&src, &mut wire);
            assert_eq!(wire.as_slice(), &scalar_wire[..], "n={n} wire");
            // Decode: codec vs scalar-only loop, compared as bits.
            let mut out = vec![0f32; n];
            Bf16.decode(&wire, &mut out).unwrap();
            for (i, (o, b)) in out.iter().zip(scalar_wire.chunks_exact(2)).enumerate() {
                let want = bf16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
                assert_eq!(o.to_bits(), want.to_bits(), "n={n} elem {i}");
            }
        }
    }
}
