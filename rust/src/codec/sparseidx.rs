//! `SparseIdx` — non-zero index coding for sparse-ish payloads: the wire
//! form carries the positions of the non-zero elements (choosing per
//! payload between a bitmap — `ceil(n/8)` bytes — and delta-varints —
//! ~1 byte per non-zero when they are dense gaps apart) and their values in
//! a configurable `ValueFormat`.  Zeros cost (almost) nothing, which is the
//! point: LSP's GATHER-layout sparse machinery (`sparse::compress`)
//! produces structurally sparse intermediates, and gradient payloads for
//! frozen/ReLU-masked parameters are zero-heavy.  `sparse-int8` (indices +
//! block-quantized values) is the LSP policy's preferred wire format: on a
//! fully dense d x d subspace gradient it still ships ~1.19 B/elem (bitmap
//! + int8 + scales) vs f32's 4 B.
//!
//! Wire layout (little-endian):
//!
//! ```text
//! u32 n | u8 mode (0=bitmap, 1=varint) | u32 nnz
//! index section:  bitmap: ceil(n/8) bytes, LSB-first
//!                 varint: nnz LEB128 gaps (first = index, then deltas)
//! value section:  nnz values in `ValueFormat` order of appearance
//! ```
//!
//! Index coding is exact; the round-trip error is exactly the value
//! format's (0 for `F32` — up to `-0.0` canonicalizing to `+0.0`).

use anyhow::{bail, ensure, Result};

use super::bf16::{bf16_bits_to_f32, f32_to_bf16_bits};
use super::int8block::{decode_block, encode_block, MAX_BLOCK};
use super::{push_varint, read_f32, read_u32, read_varint, varint_len, ByteBuf, Codec};

const MODE_BITMAP: u8 = 0;
const MODE_VARINT: u8 = 1;
const HEADER_BYTES: usize = 4 + 1 + 4;

/// How the non-zero values themselves are encoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueFormat {
    /// 4 B/value, exact.
    F32,
    /// 2 B/value, bf16 round-to-nearest-even.
    Bf16,
    /// 1 B/value + one f32 absmax scale per `block` values.
    Int8 { block: usize },
}

impl ValueFormat {
    fn bytes_for(&self, nnz: usize) -> usize {
        match *self {
            ValueFormat::F32 => 4 * nnz,
            ValueFormat::Bf16 => 2 * nnz,
            ValueFormat::Int8 { block } => nnz + 4 * nnz.div_ceil(block),
        }
    }

    fn rel_l2_bound(&self) -> f32 {
        match *self {
            ValueFormat::F32 => 0.0,
            ValueFormat::Bf16 => 1.0 / 256.0,
            ValueFormat::Int8 { block } => (block as f32).sqrt() / 240.0,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SparseIdx {
    pub values: ValueFormat,
}

impl SparseIdx {
    pub fn new(values: ValueFormat) -> SparseIdx {
        if let ValueFormat::Int8 { block } = values {
            assert!(
                (1..=MAX_BLOCK).contains(&block),
                "sparse int8 block size must be in 1..={MAX_BLOCK}, got {block}"
            );
        }
        SparseIdx { values }
    }

    /// One pass over `src`: (nnz, exact varint index bytes).
    fn scan(src: &[f32]) -> (usize, usize) {
        let mut nnz = 0usize;
        let mut vbytes = 0usize;
        let mut prev = 0usize;
        for (i, &x) in src.iter().enumerate() {
            if x != 0.0 {
                let gap = if nnz == 0 { i } else { i - prev };
                vbytes += varint_len(gap as u32);
                prev = i;
                nnz += 1;
            }
        }
        (nnz, vbytes)
    }

    /// Flush `vals` through the value format (encoder side).
    fn encode_values<'a>(&self, nonzeros: impl Iterator<Item = &'a f32>, dst: &mut ByteBuf) {
        match self.values {
            ValueFormat::F32 => {
                for &x in nonzeros {
                    dst.extend_from_slice(&x.to_le_bytes());
                }
            }
            ValueFormat::Bf16 => {
                for &x in nonzeros {
                    dst.extend_from_slice(&f32_to_bf16_bits(x).to_le_bytes());
                }
            }
            ValueFormat::Int8 { block } => {
                let mut buf = [0f32; MAX_BLOCK];
                let mut cnt = 0usize;
                for &x in nonzeros {
                    buf[cnt] = x;
                    cnt += 1;
                    if cnt == block {
                        encode_block(&buf[..cnt], dst);
                        cnt = 0;
                    }
                }
                if cnt > 0 {
                    encode_block(&buf[..cnt], dst);
                }
            }
        }
    }
}

/// Streaming decoder over the value section — refills a stack block for the
/// int8 format, so decode allocates nothing.
struct ValueReader<'a> {
    fmt: ValueFormat,
    src: &'a [u8],
    pos: usize,
    remaining: usize,
    buf: [f32; MAX_BLOCK],
    have: usize,
    used: usize,
}

impl<'a> ValueReader<'a> {
    fn new(fmt: ValueFormat, src: &'a [u8], pos: usize, nnz: usize) -> ValueReader<'a> {
        ValueReader { fmt, src, pos, remaining: nnz, buf: [0.0; MAX_BLOCK], have: 0, used: 0 }
    }

    fn next(&mut self) -> Result<f32> {
        ensure!(self.remaining > 0, "value stream over-read");
        self.remaining -= 1;
        match self.fmt {
            ValueFormat::F32 => read_f32(self.src, &mut self.pos),
            ValueFormat::Bf16 => {
                let Some(b) = self.src.get(self.pos..self.pos + 2) else {
                    bail!("bf16 value runs past the end of the payload");
                };
                self.pos += 2;
                Ok(bf16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap())))
            }
            ValueFormat::Int8 { block } => {
                if self.used == self.have {
                    // `remaining` was already decremented for this value.
                    let take = block.min(self.remaining + 1);
                    let Some(b) = self.src.get(self.pos..self.pos + 4 + take) else {
                        bail!("int8 value block runs past the end of the payload");
                    };
                    decode_block(b, &mut self.buf[..take])?;
                    self.pos += 4 + take;
                    self.have = take;
                    self.used = 0;
                }
                let v = self.buf[self.used];
                self.used += 1;
                Ok(v)
            }
        }
    }

    fn finish(self) -> Result<()> {
        ensure!(self.remaining == 0, "value stream under-read");
        ensure!(self.pos == self.src.len(), "trailing bytes after the value section");
        Ok(())
    }
}

impl Codec for SparseIdx {
    fn name(&self) -> String {
        match self.values {
            ValueFormat::F32 => "sparse-f32".to_string(),
            ValueFormat::Bf16 => "sparse-bf16".to_string(),
            ValueFormat::Int8 { block } => format!("sparse-int8-{block}"),
        }
    }

    fn encode(&self, src: &[f32], dst: &mut ByteBuf) {
        let n = src.len();
        let (nnz, vbytes) = Self::scan(src);
        let bitmap_bytes = n.div_ceil(8);
        let mode = if bitmap_bytes <= vbytes { MODE_BITMAP } else { MODE_VARINT };
        let idx_bytes = if mode == MODE_BITMAP { bitmap_bytes } else { vbytes };
        dst.reserve(HEADER_BYTES + idx_bytes + self.values.bytes_for(nnz));

        dst.extend_from_slice(&(n as u32).to_le_bytes());
        dst.push(mode);
        dst.extend_from_slice(&(nnz as u32).to_le_bytes());

        if mode == MODE_BITMAP {
            let mut acc = 0u8;
            for (i, &x) in src.iter().enumerate() {
                if x != 0.0 {
                    acc |= 1 << (i % 8);
                }
                if i % 8 == 7 {
                    dst.push(acc);
                    acc = 0;
                }
            }
            if n % 8 != 0 {
                dst.push(acc);
            }
        } else {
            let mut prev = 0usize;
            let mut first = true;
            for (i, &x) in src.iter().enumerate() {
                if x != 0.0 {
                    let gap = if first { i } else { i - prev };
                    push_varint(dst, gap as u32);
                    prev = i;
                    first = false;
                }
            }
        }

        self.encode_values(src.iter().filter(|&&x| x != 0.0), dst);
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) -> Result<()> {
        let mut pos = 0usize;
        let n = read_u32(src, &mut pos)? as usize;
        ensure!(n == dst.len(), "sparse payload holds {n} elems, caller wants {}", dst.len());
        let Some(&mode) = src.get(pos) else {
            bail!("sparse payload truncated before the mode byte");
        };
        pos += 1;
        let nnz = read_u32(src, &mut pos)? as usize;
        ensure!(nnz <= n, "sparse payload claims {nnz} non-zeros in {n} elems");
        dst.fill(0.0);

        match mode {
            MODE_BITMAP => {
                let bm_bytes = n.div_ceil(8);
                let Some(bm) = src.get(pos..pos + bm_bytes) else {
                    bail!("sparse bitmap runs past the end of the payload");
                };
                pos += bm_bytes;
                let mut vr = ValueReader::new(self.values, src, pos, nnz);
                let mut seen = 0usize;
                for (i, out) in dst.iter_mut().enumerate() {
                    if (bm[i / 8] >> (i % 8)) & 1 == 1 {
                        *out = vr.next()?;
                        seen += 1;
                    }
                }
                ensure!(seen == nnz, "bitmap has {seen} set bits, header says {nnz}");
                vr.finish()
            }
            MODE_VARINT => {
                // Pass 1: find where the index section ends (varints are
                // self-delimiting, so this needs no allocation).
                let idx_start = pos;
                let mut p = pos;
                for _ in 0..nnz {
                    read_varint(src, &mut p)?;
                }
                let mut vr = ValueReader::new(self.values, src, p, nnz);
                // Pass 2: re-walk the gaps, consuming values in step.
                let mut p = idx_start;
                let mut idx = 0usize;
                for k in 0..nnz {
                    let gap = read_varint(src, &mut p)? as usize;
                    idx = if k == 0 { gap } else { idx + gap };
                    ensure!(idx < n, "sparse index {idx} out of range (n={n})");
                    dst[idx] = vr.next()?;
                }
                vr.finish()
            }
            other => bail!("unknown sparse index mode {other}"),
        }
    }

    fn wire_len(&self, src: &[f32]) -> usize {
        let (nnz, vbytes) = Self::scan(src);
        // Same mode selection as `encode`: bitmap when not larger.
        let bitmap_bytes = src.len().div_ceil(8);
        let idx_bytes = if bitmap_bytes <= vbytes { bitmap_bytes } else { vbytes };
        HEADER_BYTES + idx_bytes + self.values.bytes_for(nnz)
    }

    fn rel_l2_bound(&self) -> f32 {
        self.values.rel_l2_bound()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(c: &SparseIdx, data: &[f32]) -> Vec<f32> {
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(data, &mut buf);
        assert_eq!(buf.len(), c.wire_len(data), "wire_len exact for {}", c.name());
        let mut out = vec![f32::NAN; data.len()];
        c.decode(&buf, &mut out).unwrap();
        out
    }

    #[test]
    fn all_zero_payload_costs_only_the_index() {
        let c = SparseIdx::new(ValueFormat::F32);
        let data = vec![0.0f32; 1000];
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(&data, &mut buf);
        // nnz=0: varint mode, zero index bytes, zero value bytes.
        assert_eq!(buf.len(), HEADER_BYTES);
        let mut out = vec![1f32; 1000];
        c.decode(&buf, &mut out).unwrap();
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn isolated_nonzeros_pick_varint_mode() {
        let c = SparseIdx::new(ValueFormat::F32);
        let mut data = vec![0.0f32; 4096];
        data[7] = 1.5;
        data[4000] = -2.5;
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(&data, &mut buf);
        assert_eq!(buf[4], MODE_VARINT, "2 nnz in 4096 must not pay a 512 B bitmap");
        assert!(buf.len() < HEADER_BYTES + 8 + 8);
        assert_eq!(roundtrip(&c, &data), data);
    }

    #[test]
    fn dense_payload_picks_bitmap_mode() {
        let mut rng = Rng::new(4);
        let c = SparseIdx::new(ValueFormat::F32);
        let data: Vec<f32> = (0..256).map(|_| rng.normal() + 10.0).collect();
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(&data, &mut buf);
        assert_eq!(buf[4], MODE_BITMAP);
        assert_eq!(buf.len(), HEADER_BYTES + 32 + 4 * 256);
        assert_eq!(roundtrip(&c, &data), data);
    }

    #[test]
    fn sparse_int8_beats_half_of_f32_on_dense_data() {
        // The acceptance-criterion shape: a fully dense subspace gradient
        // must still ship in <= 50% of the raw f32 bytes.
        let mut rng = Rng::new(9);
        let c = SparseIdx::new(ValueFormat::Int8 { block: 64 });
        let data: Vec<f32> = (0..64 * 64).map(|_| rng.normal()).collect();
        let wire = c.wire_len(&data);
        assert!(
            wire * 2 <= data.len() * 4,
            "dense sparse-int8 wire {wire} vs f32 {}",
            data.len() * 4
        );
        let out = roundtrip(&c, &data);
        // Values land within the block-quant bound.
        let (mut err2, mut ref2) = (0f64, 0f64);
        for (&a, &b) in data.iter().zip(&out) {
            err2 += ((a - b) as f64).powi(2);
            ref2 += (a as f64).powi(2);
        }
        assert!((err2 / ref2).sqrt() <= c.rel_l2_bound() as f64);
    }

    #[test]
    fn value_formats_align_with_partial_last_block() {
        // nnz not a multiple of the int8 block: the last short block must
        // encode/decode in lockstep.
        let c = SparseIdx::new(ValueFormat::Int8 { block: 4 });
        let data = [0.0f32, 1.0, 0.0, 2.0, 3.0, 0.0, 4.0, 5.0, 6.0, 0.0, 7.0];
        let out = roundtrip(&c, &data);
        for (i, (&a, &b)) in data.iter().zip(&out).enumerate() {
            assert!((a - b).abs() <= a.abs() / 100.0 + 1e-6, "elem {i}: {a} vs {b}");
        }
        // Bf16 values too.
        let c = SparseIdx::new(ValueFormat::Bf16);
        let out = roundtrip(&c, &data);
        for (&a, &b) in data.iter().zip(&out) {
            assert!((a - b).abs() <= a.abs() / 128.0);
        }
    }

    #[test]
    fn corrupt_payloads_fail_loudly() {
        let c = SparseIdx::new(ValueFormat::F32);
        let data = [1.0f32, 0.0, 2.0];
        let mut buf = ByteBuf::detached(Vec::new());
        c.encode(&data, &mut buf);
        let wire = buf.into_vec();
        let mut out = [0f32; 3];
        // Truncated value section.
        assert!(c.decode(&wire[..wire.len() - 1], &mut out).is_err());
        // Trailing garbage.
        let mut long = wire.clone();
        long.push(0xAB);
        assert!(c.decode(&long, &mut out).is_err());
        // nnz larger than n.
        let mut bad = wire.clone();
        bad[5..9].copy_from_slice(&100u32.to_le_bytes());
        assert!(c.decode(&bad, &mut out).is_err());
    }
}
