//! Numerical linear algebra for the baselines and analyses:
//! modified Gram-Schmidt QR, randomized subspace-iteration SVD (GaLore's
//! projector), and an effective-rank estimator (Fig. 4 study).
//!
//! §Perf pass: everything here rides the blocked kernel substrate — the
//! GEMMs inside `randomized_svd` (including the U/V reconstruction, now
//! expressed as GEMMs instead of scalar loops) dispatch through
//! `tensor::ops`, and QR works on A^T so its column operations become
//! contiguous, vectorizable row operations.

use anyhow::Result;

use crate::tensor::kernel::{self, KernelConfig};
use crate::tensor::ops::{dot, matmul_nt_with, matmul_tn_with, matmul_with, transpose};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Thin QR via modified Gram-Schmidt *with re-orthogonalization* ("twice is
/// enough"), robust to rank-deficient input: columns whose residual norm
/// falls below a relative tolerance are zeroed rather than normalized into
/// noise.  Returns (Q [m, k], R [k, k]) with A = Q R and Q^T Q = I on the
/// non-zero columns.
///
/// Internally operates on A^T so each column lives in one contiguous,
/// cache-friendly row (same arithmetic, same order — results are
/// bit-identical to the column-strided form).
pub fn qr(a: &Tensor) -> (Tensor, Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let mut qt = transpose(a); // [k, m]: row j is column j of A
    let qtd = qt.data_mut();
    let mut r = Tensor::zeros(&[k, k]);
    let tol = 1e-6f32 * a.frob_norm().max(1e-30);
    for j in 0..k {
        for _pass in 0..2 {
            for l in 0..j {
                let (head, tail) = qtd.split_at_mut(j * m);
                let ql = &head[l * m..(l + 1) * m];
                let qj = &mut tail[..m];
                let proj = dot(ql, qj);
                if proj != 0.0 {
                    let rv = r.at2(l, j) + proj;
                    r.set2(l, j, rv);
                    for (x, &y) in qj.iter_mut().zip(ql) {
                        *x -= proj * y;
                    }
                }
            }
        }
        let qj = &mut qtd[j * m..(j + 1) * m];
        let norm =
            qj.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32;
        if norm <= tol {
            // Rank-deficient direction: zero it out entirely.
            r.set2(j, j, 0.0);
            qj.fill(0.0);
        } else {
            r.set2(j, j, norm);
            let inv = 1.0 / norm;
            for x in qj.iter_mut() {
                *x *= inv;
            }
        }
    }
    (transpose(&qt), r)
}

/// Result of a truncated SVD: A ~ U diag(S) V^T.
pub struct Svd {
    pub u: Tensor, // [m, k]
    pub s: Vec<f32>,
    pub v: Tensor, // [n, k]
}

/// Randomized subspace-iteration SVD (Halko et al.) — how GaLore computes
/// its rank-k projector `P = [u_1..u_k]` from a gradient matrix.  Uses the
/// process-wide `KernelConfig`.
pub fn randomized_svd(a: &Tensor, k: usize, iters: usize, rng: &mut Rng) -> Result<Svd> {
    randomized_svd_with(a, k, iters, rng, &kernel::current())
}

/// `randomized_svd` under an explicit per-instance `KernelConfig` (the
/// coordinator threads its negotiated config through here via the GaLore
/// baseline instead of relying on a process-wide install).
pub fn randomized_svd_with(
    a: &Tensor,
    k: usize,
    iters: usize,
    rng: &mut Rng,
    cfg: &KernelConfig,
) -> Result<Svd> {
    let (m, n) = (a.rows(), a.cols());
    let k = k.min(m).min(n);
    let over = (k + 4).min(n.min(m)); // small oversampling
    let omega = Tensor::randn(&[n, over], 1.0, rng);
    let mut y = matmul_with(a, &omega, cfg)?; // [m, over]
    for _ in 0..iters {
        let (qy, _) = qr(&y);
        let z = matmul_tn_with(a, &qy, cfg)?; // [n, over] = A^T Q
        let (qz, _) = qr(&z);
        y = matmul_with(a, &qz, cfg)?;
    }
    let (q, _) = qr(&y); // [m, over]
    let b = matmul_tn_with(&q, a, cfg)?; // [over, n]
    // SVD of the small matrix B via eigen-decomposition of B B^T (Jacobi).
    let bbt = matmul_nt_with(&b, &b, cfg)?; // [over, over]
    let (evals, evecs) = sym_eig_jacobi(&bbt, 100);
    // Sort descending and gather the selected eigenvectors as columns, so
    // the U/V reconstruction is two blocked GEMMs instead of scalar loops.
    let mut order: Vec<usize> = (0..over).collect();
    order.sort_by(|&i, &j| evals[j].total_cmp(&evals[i]));
    let mut sel = Tensor::zeros(&[over, k]);
    let mut s = Vec::with_capacity(k);
    for (col, &oi) in order.iter().take(k).enumerate() {
        s.push(evals[oi].max(0.0).sqrt());
        for l in 0..over {
            sel.set2(l, col, evecs.at2(l, oi));
        }
    }
    // U = Q sel;  V = B^T sel with columns rescaled by 1/sigma (zeroed for
    // numerically-vanishing singular values, matching the scalar original).
    let u = matmul_with(&q, &sel, cfg)?; // [m, k]
    let mut v = matmul_tn_with(&b, &sel, cfg)?; // [n, k]
    for (col, &sigma) in s.iter().enumerate() {
        let scale = if sigma > 1e-12 { 1.0 / sigma } else { 0.0 };
        for i in 0..n {
            v.set2(i, col, v.at2(i, col) * scale);
        }
    }
    Ok(Svd { u, s, v })
}

/// Cyclic Jacobi eigen-decomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors-as-columns).
pub fn sym_eig_jacobi(a: &Tensor, max_sweeps: usize) -> (Vec<f32>, Tensor) {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut m = a.clone();
    let mut v = Tensor::zeros(&[n, n]);
    for i in 0..n {
        v.set2(i, i, 1.0);
    }
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += (m.at2(p, q) as f64).powi(2);
            }
        }
        if off.sqrt() < 1e-10 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at2(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = m.at2(p, p);
                let aqq = m.at2(q, q);
                let theta = 0.5 * (aqq - app) as f64 / apq as f64;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                let (c, s) = (c as f32, s as f32);
                for i in 0..n {
                    let mip = m.at2(i, p);
                    let miq = m.at2(i, q);
                    m.set2(i, p, c * mip - s * miq);
                    m.set2(i, q, s * mip + c * miq);
                }
                for i in 0..n {
                    let mpi = m.at2(p, i);
                    let mqi = m.at2(q, i);
                    m.set2(p, i, c * mpi - s * mqi);
                    m.set2(q, i, s * mpi + c * mqi);
                }
                for i in 0..n {
                    let vip = v.at2(i, p);
                    let viq = v.at2(i, q);
                    v.set2(i, p, c * vip - s * viq);
                    v.set2(i, q, s * vip + c * viq);
                }
            }
        }
    }
    let evals = (0..n).map(|i| m.at2(i, i)).collect();
    (evals, v)
}

/// Effective rank (participation ratio of singular values):
/// `(sum s_i)^2 / sum s_i^2`.  Used for the Fig. 4 optimization-space study.
pub fn effective_rank(a: &Tensor, probe: usize, rng: &mut Rng) -> Result<f64> {
    let svd = randomized_svd(a, probe.min(a.rows()).min(a.cols()), 2, rng)?;
    let sum: f64 = svd.s.iter().map(|&x| x as f64).sum();
    let sq: f64 = svd.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if sq <= 0.0 {
        return Ok(0.0);
    }
    Ok(sum * sum / sq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul, matmul_tn};
    use crate::util::prop::check;

    #[test]
    fn qr_orthonormal_and_reconstructs() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(&[20, 6], 1.0, &mut rng);
        let (q, r) = qr(&a);
        // Q^T Q = I
        let qtq = matmul_tn(&q, &q).unwrap();
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at2(i, j) - want).abs() < 1e-4, "qtq[{i}][{j}]");
            }
        }
        // QR = A
        let back = matmul(&q, &r).unwrap();
        assert!(back.allclose(&a, 1e-4));
    }

    #[test]
    fn jacobi_recovers_diagonal() {
        let mut d = Tensor::zeros(&[3, 3]);
        d.set2(0, 0, 3.0);
        d.set2(1, 1, -1.0);
        d.set2(2, 2, 0.5);
        let (mut evals, _) = sym_eig_jacobi(&d, 10);
        evals.sort_by(|a, b| b.total_cmp(a));
        assert!((evals[0] - 3.0).abs() < 1e-6);
        assert!((evals[1] - 0.5).abs() < 1e-6);
        assert!((evals[2] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn svd_recovers_low_rank_matrix() {
        let mut rng = Rng::new(2);
        // Build an exactly rank-3 matrix.
        let u = Tensor::randn(&[24, 3], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 18], 1.0, &mut rng);
        let a = matmul(&u, &v).unwrap();
        let svd = randomized_svd(&a, 3, 3, &mut rng).unwrap();
        // Reconstruction error should be tiny.
        let mut recon = Tensor::zeros(&[24, 18]);
        for col in 0..3 {
            for i in 0..24 {
                for j in 0..18 {
                    let val = recon.at2(i, j)
                        + svd.s[col] * svd.u.at2(i, col) * svd.v.at2(j, col);
                    recon.set2(i, j, val);
                }
            }
        }
        let rel = crate::tensor::ops::sub(&recon, &a).frob_norm() / a.frob_norm();
        assert!(rel < 1e-3, "rel recon err {rel}");
    }

    #[test]
    fn svd_singular_values_sorted_nonneg() {
        check(
            "svd-sorted",
            8,
            |r| {
                let m = 6 + r.below(20);
                let n = 6 + r.below(20);
                Tensor::randn(&[m, n], 1.0, r)
            },
            |a| {
                let mut rng = Rng::new(99);
                let svd = randomized_svd(a, 4, 2, &mut rng).map_err(|e| e.to_string())?;
                for w in svd.s.windows(2) {
                    if w[1] > w[0] + 1e-4 {
                        return Err(format!("unsorted {:?}", svd.s));
                    }
                }
                if svd.s.iter().any(|&s| s < 0.0) {
                    return Err("negative sv".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn effective_rank_of_low_rank() {
        let mut rng = Rng::new(4);
        let u = Tensor::randn(&[30, 2], 1.0, &mut rng);
        let v = Tensor::randn(&[2, 30], 1.0, &mut rng);
        let a = matmul(&u, &v).unwrap();
        let er = effective_rank(&a, 8, &mut rng).unwrap();
        assert!(er < 2.5, "effective rank {er} for rank-2 matrix");
        let full = Tensor::randn(&[30, 30], 1.0, &mut rng);
        let er_full = effective_rank(&full, 16, &mut rng).unwrap();
        assert!(er_full > er, "full {er_full} vs low {er}");
    }
}
