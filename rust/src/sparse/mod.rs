//! (d, r)-sparse projectors — Definition 1 of the paper.
//!
//! Mirrors `python/compile/kernels/formats.py` exactly (shapes, balanced
//! position sampling, padded gather layout); the Pallas compress kernel
//! consumes the GATHER layout, the decompress/learn entries the ROW layout.
//! Host-side compress/apply/bias here serve three roles: oracle for the
//! runtime artifacts in integration tests, compute path for CPU-side
//! baselines, and the projector manager's cheap bias estimates.
//!
//! §Perf pass: `compress`/`decompress` run on the blocked kernel substrate
//! — compress streams the GATHER layout (contiguous output rows, vectorized
//! row axpys) instead of walking the ROW layout scalar-by-scalar, and both
//! directions split their output rows across the `tensor::pool` workers.
//! The original single-threaded ROW-layout walks survive as
//! `compress_ref`/`decompress_ref` oracles.

use anyhow::{bail, Result};

use crate::tensor::kernel::{self, KernelConfig};
use crate::tensor::ops::{matmul, matmul_tn};
use crate::tensor::{pool, Tensor};
use crate::util::rng::Rng;

/// One (d, r)-sparse projector in ROW layout: `rows x d` with exactly `r`
/// non-zeros per row at `idx`, values `val` (both `[rows, r]` row-major).
#[derive(Debug, Clone)]
pub struct SparseProjector {
    pub rows: usize,
    pub d: usize,
    pub r: usize,
    pub idx: Vec<i32>,
    pub val: Vec<f32>,
}

impl SparseProjector {
    /// Balanced random positions + JL `N(0, 1/sqrt(r))` values.
    ///
    /// For each of the r hash functions, rows are randomly permuted and
    /// dealt round-robin over the d subspace columns, so each column
    /// receives exactly `ceil(rows/d)` entries per hash — this makes the
    /// padded gather length static (`gather_len`), which the AOT artifacts
    /// require.
    pub fn init(rows: usize, d: usize, r: usize, rng: &mut Rng) -> Self {
        assert!(r > 0 && r <= d, "need 0 < r <= d");
        let mut idx = vec![0i32; rows * r];
        for k in 0..r {
            let perm = rng.permutation(rows);
            for (i, &row) in perm.iter().enumerate() {
                idx[row * r + k] = (i % d) as i32;
            }
        }
        let std = 1.0 / (r as f32).sqrt();
        let val = rng.normal_vec(rows * r, std);
        SparseProjector { rows, d, r, idx, val }
    }

    /// Static padded gather length: `r * ceil(rows / d)`.
    pub fn gather_len(&self) -> usize {
        self.r * self.rows.div_ceil(self.d)
    }

    /// GATHER layout (padded CSC of P^T): `(gidx, gval)`, both `[d, L]`.
    /// Padding slots are (index 0, value 0).  Entries within a subspace
    /// column appear in (row, hash) order, so accumulating a column in
    /// gather order reproduces the ROW-layout accumulation order exactly.
    pub fn to_gather(&self) -> Result<(Vec<i32>, Vec<f32>)> {
        let l = self.gather_len();
        let mut gidx = vec![0i32; self.d * l];
        let mut gval = vec![0f32; self.d * l];
        let mut fill = vec![0usize; self.d];
        for i in 0..self.rows {
            for k in 0..self.r {
                let j = self.idx[i * self.r + k] as usize;
                if fill[j] >= l {
                    bail!("column {j} load exceeds static gather length {l}");
                }
                gidx[j * l + fill[j]] = i as i32;
                gval[j * l + fill[j]] = self.val[i * self.r + k];
                fill[j] += 1;
            }
        }
        Ok((gidx, gval))
    }

    /// Dense `[rows, d]` matrix (duplicate positions accumulate).
    pub fn densify(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.d]);
        for i in 0..self.rows {
            for k in 0..self.r {
                let j = self.idx[i * self.r + k] as usize;
                let v = t.at2(i, j) + self.val[i * self.r + k];
                t.set2(i, j, v);
            }
        }
        t
    }

    /// Memory held on the "GPU" for this projector: idx (i32) + val (f32).
    pub fn nnz_bytes(&self) -> usize {
        self.rows * self.r * 8
    }
}

/// The pair (P, Q) attached to one weight matrix `W in R^{m x n}`.
#[derive(Debug, Clone)]
pub struct ProjectorPair {
    pub p: SparseProjector, // [m, d]
    pub q: SparseProjector, // [n, d]
}

impl ProjectorPair {
    pub fn init(m: usize, n: usize, d: usize, r: usize, rng: &mut Rng) -> Self {
        ProjectorPair {
            p: SparseProjector::init(m, d, r, rng),
            q: SparseProjector::init(n, d, r, rng),
        }
    }

    /// Compress: `S = P^T G Q`, `[d, d]` (GATHER-streamed, parallel over
    /// output rows; see module docs).  Uses the process-wide
    /// `KernelConfig`.
    pub fn compress(&self, g: &Tensor) -> Result<Tensor> {
        self.compress_with(g, &kernel::current())
    }

    pub fn compress_with(&self, g: &Tensor, cfg: &KernelConfig) -> Result<Tensor> {
        let d = self.p.d;
        let mut s = Tensor::zeros(&[d, d]);
        // Freshly zeroed allocation: skip the redundant fill in the
        // reuse-oriented entry below.
        self.compress_zeroed(g, cfg, s.data_mut())?;
        Ok(s)
    }

    /// Compress into a caller-provided `[d, d]` buffer, overwriting its
    /// contents, so callers can reuse storage (e.g. a `PooledBuf` payload)
    /// instead of allocating per call.  The `_with` wrappers route through
    /// the same kernel body; the trainer's LSP path compresses on the GPU,
    /// so today the recurring host-side callers are the bias checks and
    /// CPU baselines.
    pub fn compress_into_with(&self, g: &Tensor, cfg: &KernelConfig, out: &mut [f32]) -> Result<()> {
        out.fill(0.0);
        self.compress_zeroed(g, cfg, out)
    }

    /// Kernel body; `out` must be a zeroed `[d, d]` buffer (accumulates).
    fn compress_zeroed(&self, g: &Tensor, cfg: &KernelConfig, out: &mut [f32]) -> Result<()> {
        let (m, n) = (g.rows(), g.cols());
        if m != self.p.rows || n != self.q.rows {
            bail!("compress shape mismatch: G {:?} vs P rows {} / Q rows {}",
                  g.shape(), self.p.rows, self.q.rows);
        }
        let d = self.p.d;
        if out.len() != d * d {
            bail!("compress output wants {} elements, got {}", d * d, out.len());
        }
        let threads = cfg.resolved_threads();

        // A = P^T G, streamed through P's GATHER layout: row j of A is the
        // weighted sum of the G rows listed in gather column j, so every
        // output row is written once, contiguously, by exactly one worker,
        // and the inner loop is a vectorizable row axpy.
        //
        // The layout is rebuilt per call rather than cached: the projector
        // manager rewrites `val` in place after learning, so a cache could
        // go silently stale, and the O(nnz) rebuild is 1/n of the O(nnz*n)
        // compute below.
        let (pgi, pgv) = self.p.to_gather()?;
        let lp = self.p.gather_len();
        let gd = g.data();
        let mut a = Tensor::zeros(&[d, n]);
        pool::par_row_blocks(threads, d, n, 4, a.data_mut(), |rows, block| {
            for (local, j) in rows.enumerate() {
                let arow = &mut block[local * n..(local + 1) * n];
                let base = j * lp;
                for t in 0..lp {
                    let v = pgv[base + t];
                    if v == 0.0 {
                        continue; // padding slot (or a zero-valued entry)
                    }
                    let src = pgi[base + t] as usize;
                    let grow = &gd[src * n..(src + 1) * n];
                    for (av, gv) in arow.iter_mut().zip(grow) {
                        *av += v * gv;
                    }
                }
            }
        });

        // S = A Q: walk rows of A so both the read stream (A row) and the
        // write stream (S row) stay contiguous, parallel over S rows
        // (see ROADMAP.md §Perf).
        let ad = a.data();
        let (q_idx, q_val, q_r) = (&self.q.idx, &self.q.val, self.q.r);
        pool::par_row_blocks(threads, d, d, 4, out, |rows, block| {
            for (local, row) in rows.enumerate() {
                let arow = &ad[row * n..(row + 1) * n];
                let srow = &mut block[local * d..(local + 1) * d];
                for (jn, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let base = jn * q_r;
                    for t in 0..q_r {
                        srow[q_idx[base + t] as usize] += q_val[base + t] * av;
                    }
                }
            }
        });
        Ok(())
    }

    /// Reference compress: the original single-threaded ROW-layout walk
    /// (oracle for the streamed implementation and the artifacts).
    pub fn compress_ref(&self, g: &Tensor) -> Result<Tensor> {
        let (m, n) = (g.rows(), g.cols());
        if m != self.p.rows || n != self.q.rows {
            bail!("compress shape mismatch: G {:?} vs P rows {} / Q rows {}",
                  g.shape(), self.p.rows, self.q.rows);
        }
        let d = self.p.d;
        // A = P^T G: scatter-add rows of G.
        let mut a = Tensor::zeros(&[d, n]);
        let gd = g.data();
        let ad = a.data_mut();
        for i in 0..m {
            let grow = &gd[i * n..(i + 1) * n];
            for k in 0..self.p.r {
                let j = self.p.idx[i * self.p.r + k] as usize;
                let v = self.p.val[i * self.p.r + k];
                if v == 0.0 {
                    continue;
                }
                let arow = &mut ad[j * n..(j + 1) * n];
                for (av, gv) in arow.iter_mut().zip(grow) {
                    *av += v * gv;
                }
            }
        }
        // S = A Q.
        let mut s = Tensor::zeros(&[d, d]);
        let ad = a.data();
        let sd = s.data_mut();
        for row in 0..d {
            let arow = &ad[row * n..(row + 1) * n];
            let srow = &mut sd[row * d..(row + 1) * d];
            for (jn, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let base = jn * self.q.r;
                for k in 0..self.q.r {
                    let c = self.q.idx[base + k] as usize;
                    srow[c] += self.q.val[base + k] * av;
                }
            }
        }
        Ok(s)
    }

    /// Decompress the subspace delta back: `D = P dS Q^T`, `[m, n]`
    /// (parallel over output rows).
    pub fn decompress(&self, ds: &Tensor) -> Result<Tensor> {
        self.decompress_with(ds, &kernel::current())
    }

    pub fn decompress_with(&self, ds: &Tensor, cfg: &KernelConfig) -> Result<Tensor> {
        let (m, n) = (self.p.rows, self.q.rows);
        let mut y = Tensor::zeros(&[m, n]);
        // Freshly zeroed allocation: skip the redundant fill.
        self.decompress_zeroed(ds, cfg, y.data_mut())?;
        Ok(y)
    }

    /// Decompress into a caller-provided `[m, n]` buffer, overwriting its
    /// contents (storage-reuse variant; see `compress_into_with`).
    pub fn decompress_into_with(&self, ds: &Tensor, cfg: &KernelConfig, out: &mut [f32]) -> Result<()> {
        out.fill(0.0);
        self.decompress_zeroed(ds, cfg, out)
    }

    /// Kernel body; `out` must be a zeroed `[m, n]` buffer (accumulates).
    fn decompress_zeroed(&self, ds: &Tensor, cfg: &KernelConfig, out: &mut [f32]) -> Result<()> {
        let d = self.p.d;
        if ds.rows() != d || ds.cols() != d {
            bail!("decompress wants [{d},{d}], got {:?}", ds.shape());
        }
        let (m, n) = (self.p.rows, self.q.rows);
        if out.len() != m * n {
            bail!("decompress output wants {} elements, got {}", m * n, out.len());
        }
        let threads = cfg.resolved_threads();

        // X = P dS: each output row gathers r rows of dS (vectorized row
        // axpys; rows are independent, so the pool splits them).
        let dsd = ds.data();
        let (p_idx, p_val, p_r) = (&self.p.idx, &self.p.val, self.p.r);
        let mut x = Tensor::zeros(&[m, d]);
        pool::par_row_blocks(threads, m, d, 16, x.data_mut(), |rows, block| {
            for (local, i) in rows.enumerate() {
                let xrow = &mut block[local * d..(local + 1) * d];
                let base = i * p_r;
                for t in 0..p_r {
                    let v = p_val[base + t];
                    if v == 0.0 {
                        continue;
                    }
                    let dsrow = &dsd[p_idx[base + t] as usize * d..][..d];
                    for (xv, dv) in xrow.iter_mut().zip(dsrow) {
                        *xv += v * dv;
                    }
                }
            }
        });

        // Y = X Q^T: out[i, j] = sum_k q_val[j,k] * X[i, q_idx[j,k]].
        // Walk output rows so writes are contiguous and the X row stays hot.
        let xd = x.data();
        let (q_idx, q_val, q_r) = (&self.q.idx, &self.q.val, self.q.r);
        pool::par_row_blocks(threads, m, n, 8, out, |rows, block| {
            for (local, i) in rows.enumerate() {
                let xrow = &xd[i * d..(i + 1) * d];
                let yrow = &mut block[local * n..(local + 1) * n];
                for (jn, yv) in yrow.iter_mut().enumerate() {
                    let base = jn * q_r;
                    let mut acc = 0.0f32;
                    for t in 0..q_r {
                        acc += q_val[base + t] * xrow[q_idx[base + t] as usize];
                    }
                    *yv += acc;
                }
            }
        });
        Ok(())
    }

    /// Reference decompress: original single-threaded walk (oracle).
    pub fn decompress_ref(&self, ds: &Tensor) -> Result<Tensor> {
        let d = self.p.d;
        if ds.rows() != d || ds.cols() != d {
            bail!("decompress wants [{d},{d}], got {:?}", ds.shape());
        }
        let (m, n) = (self.p.rows, self.q.rows);
        let mut x = Tensor::zeros(&[m, d]);
        let dsd = ds.data();
        let xd = x.data_mut();
        for i in 0..m {
            for k in 0..self.p.r {
                let j = self.p.idx[i * self.p.r + k] as usize;
                let v = self.p.val[i * self.p.r + k];
                let xrow = &mut xd[i * d..(i + 1) * d];
                let dsrow = &dsd[j * d..(j + 1) * d];
                for (xv, dv) in xrow.iter_mut().zip(dsrow) {
                    *xv += v * dv;
                }
            }
        }
        let mut y = Tensor::zeros(&[m, n]);
        let xd = x.data();
        let yd = y.data_mut();
        for i in 0..m {
            let xrow = &xd[i * d..(i + 1) * d];
            let yrow = &mut yd[i * n..(i + 1) * n];
            for (jn, yv) in yrow.iter_mut().enumerate() {
                let base = jn * self.q.r;
                let mut acc = 0.0f32;
                for k in 0..self.q.r {
                    let c = self.q.idx[base + k] as usize;
                    acc += self.q.val[base + k] * xrow[c];
                }
                *yv += acc;
            }
        }
        Ok(y)
    }

    /// Apply: `W <- W - lr * P dS Q^T` (Alg. 1 line 17).
    pub fn apply(&self, w: &mut Tensor, ds: &Tensor, lr: f32) -> Result<()> {
        let delta = self.decompress(ds)?;
        crate::tensor::ops::axpy(w, -lr, &delta);
        Ok(())
    }

    /// Estimation bias `b(G) = P P^T G Q Q^T - G` (Definition 2); returns
    /// `(rel, abs, ||G||_F)` with `rel = abs / ||G||_F`.
    pub fn bias(&self, g: &Tensor) -> Result<(f32, f32, f32)> {
        self.bias_with(g, &kernel::current())
    }

    /// Bias estimate under an explicit per-instance `KernelConfig` (the
    /// projector manager's check path).  The difference is formed in place
    /// (`est + (-1)·g`, exact IEEE negation) to avoid a third allocation.
    pub fn bias_with(&self, g: &Tensor, cfg: &KernelConfig) -> Result<(f32, f32, f32)> {
        let s = self.compress_with(g, cfg)?;
        let mut est = self.decompress_with(&s, cfg)?;
        crate::tensor::ops::axpy(&mut est, -1.0, g);
        let abs = est.frob_norm();
        let gn = g.frob_norm().max(1e-30);
        Ok((abs / gn, abs, gn))
    }

    /// Dense-oracle compress (for tests): densify + two GEMMs.
    pub fn compress_dense(&self, g: &Tensor) -> Result<Tensor> {
        let p = self.p.densify();
        let q = self.q.densify();
        matmul(&matmul_tn(&p, g)?, &q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, close_rel_frob};

    #[test]
    fn balanced_positions_exact_loads() {
        let mut rng = Rng::new(0);
        let p = SparseProjector::init(96, 16, 3, &mut rng);
        let l = p.gather_len();
        assert_eq!(l, 3 * 6);
        let mut loads = vec![0usize; 16];
        for &j in &p.idx {
            loads[j as usize] += 1;
        }
        for &ld in &loads {
            assert_eq!(ld, l, "every column receives exactly L entries");
        }
        p.to_gather().unwrap(); // must not overflow
    }

    /// GATHER -> ROW -> dense round-trip: the dense matrix reconstructed
    /// from the gather layout must equal `densify()` of the ROW layout,
    /// and every non-padding gather entry must map back to a ROW entry.
    #[test]
    fn to_gather_round_trips_with_row_layout() {
        check(
            "gather-row-roundtrip",
            12,
            |r| {
                let rows = 8 + r.below(60);
                let d = 2 + r.below(20);
                let rr = 1 + r.below(3.min(d));
                SparseProjector::init(rows, d, rr, r)
            },
            |p| {
                let l = p.gather_len();
                let (gidx, gval) = p.to_gather().map_err(|e| e.to_string())?;
                if gidx.len() != p.d * l || gval.len() != p.d * l {
                    return Err("gather layout shape".into());
                }
                // Dense from GATHER: entry (gidx[j][t], j) += gval[j][t].
                let mut dense = Tensor::zeros(&[p.rows, p.d]);
                for j in 0..p.d {
                    for t in 0..l {
                        let v = gval[j * l + t];
                        if v == 0.0 {
                            continue;
                        }
                        let i = gidx[j * l + t] as usize;
                        if i >= p.rows {
                            return Err(format!("gather row {i} out of range"));
                        }
                        dense.set2(i, j, dense.at2(i, j) + v);
                    }
                }
                // ROW -> dense must agree (non-zero values: N(0, 1/sqrt r),
                // zero draws have probability ~0 but cost us nothing).
                if !dense.allclose(&p.densify(), 0.0) {
                    return Err("gather-dense != row-dense".into());
                }
                Ok(())
            },
        );
    }

    /// The streamed/parallel paths must match the single-threaded ROW
    /// oracles (bit-identical per row; compared at 1e-6 relative
    /// Frobenius for slack).
    #[test]
    fn streamed_compress_decompress_match_refs() {
        check(
            "sparse-streamed-vs-ref",
            12,
            |r| {
                let m = 8 + r.below(48);
                let n = 8 + r.below(48);
                let d = 4 + r.below(m.min(n).saturating_sub(4).max(1));
                let rr = 1 + r.below(3.min(d));
                let pair = ProjectorPair::init(m, n, d, rr, r);
                let g = Tensor::randn(&[m, n], 1.0, r);
                let ds = Tensor::randn(&[d, d], 1.0, r);
                let cfg = KernelConfig::with_threads(1 + r.below(4));
                (pair, g, ds, cfg)
            },
            |(pair, g, ds, cfg)| {
                close_rel_frob(
                    &pair.compress_with(g, cfg).map_err(|e| e.to_string())?,
                    &pair.compress_ref(g).map_err(|e| e.to_string())?,
                    1e-6,
                )
                .map_err(|e| format!("compress: {e}"))?;
                close_rel_frob(
                    &pair.decompress_with(ds, cfg).map_err(|e| e.to_string())?,
                    &pair.decompress_ref(ds).map_err(|e| e.to_string())?,
                    1e-6,
                )
                .map_err(|e| format!("decompress: {e}"))?;
                Ok(())
            },
        );
    }

    /// `_into_with` overwrites (not accumulates) a reused buffer, so a
    /// pooled payload can be recycled across steps without zeroing.
    #[test]
    fn into_variants_overwrite_reused_buffers() {
        let mut rng = Rng::new(21);
        let pair = ProjectorPair::init(24, 20, 8, 2, &mut rng);
        let cfg = KernelConfig::with_threads(2);
        let g = Tensor::randn(&[24, 20], 1.0, &mut rng);
        let want = pair.compress_with(&g, &cfg).unwrap();
        let mut buf = vec![7.0f32; 8 * 8]; // poisoned contents
        pair.compress_into_with(&g, &cfg, &mut buf).unwrap();
        assert_eq!(buf, want.data());
        // Wrong-size buffers are rejected, not silently truncated.
        let mut short = vec![0f32; 10];
        assert!(pair.compress_into_with(&g, &cfg, &mut short).is_err());

        let ds = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let dwant = pair.decompress_with(&ds, &cfg).unwrap();
        let mut dbuf = vec![-3.0f32; 24 * 20];
        pair.decompress_into_with(&ds, &cfg, &mut dbuf).unwrap();
        assert_eq!(dbuf, dwant.data());
        assert!(pair.decompress_into_with(&ds, &cfg, &mut short).is_err());
    }

    #[test]
    fn compress_matches_dense_oracle() {
        check(
            "sparse-compress-vs-dense",
            10,
            |r| {
                let m = 8 + r.below(40);
                let n = 8 + r.below(40);
                let d = 4 + r.below(m.min(n).saturating_sub(4).max(1));
                let rr = 1 + r.below(3.min(d));
                let pair = ProjectorPair::init(m, n, d, rr, r);
                let g = Tensor::randn(&[m, n], 1.0, r);
                (pair, g)
            },
            |(pair, g)| {
                let fast = pair.compress(g).map_err(|e| e.to_string())?;
                let slow = pair.compress_dense(g).map_err(|e| e.to_string())?;
                if fast.allclose(&slow, 1e-3) {
                    Ok(())
                } else {
                    Err(format!("diff {}", fast.max_abs_diff(&slow)))
                }
            },
        );
    }

    #[test]
    fn decompress_matches_dense_oracle() {
        let mut rng = Rng::new(5);
        let pair = ProjectorPair::init(24, 30, 8, 2, &mut rng);
        let ds = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let fast = pair.decompress(&ds).unwrap();
        let p = pair.p.densify();
        let q = pair.q.densify();
        let slow = matmul(&matmul(&p, &ds).unwrap(), &crate::tensor::ops::transpose(&q)).unwrap();
        assert!(fast.allclose(&slow, 1e-4));
    }

    #[test]
    fn bias_zero_when_projector_identity_like() {
        // With d == m == n and P = Q = I (r=1, idx=i, val=1), bias is 0.
        let n = 12;
        let mut p = SparseProjector::init(n, n, 1, &mut Rng::new(1));
        for i in 0..n {
            p.idx[i] = i as i32;
            p.val[i] = 1.0;
        }
        let pair = ProjectorPair { p: p.clone(), q: p };
        let g = Tensor::randn(&[n, n], 1.0, &mut Rng::new(2));
        let (rel, _, _) = pair.bias(&g).unwrap();
        assert!(rel < 1e-5, "identity projector bias {rel}");
    }

    #[test]
    fn bias_decreases_with_d() {
        // Paper Fig. 9: increasing d consistently reduces estimation bias.
        let mut rng = Rng::new(7);
        let g = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for d in [8, 16, 32, 64] {
            // Average over a few random projectors to reduce variance.
            let mut acc = 0.0;
            for s in 0..5 {
                let mut r2 = Rng::new(100 + s);
                let pair = ProjectorPair::init(64, 64, d, 2, &mut r2);
                acc += pair.bias(&g).unwrap().0;
            }
            let b = acc / 5.0;
            assert!(b < last * 1.05, "bias did not shrink: d={d} bias={b} last={last}");
            last = b;
        }
    }

    #[test]
    fn apply_changes_weights_in_descent_direction() {
        let mut rng = Rng::new(9);
        let pair = ProjectorPair::init(16, 16, 8, 2, &mut rng);
        let mut w = Tensor::zeros(&[16, 16]);
        let ds = Tensor::full(&[8, 8], 1.0);
        pair.apply(&mut w, &ds, 0.1).unwrap();
        let delta = pair.decompress(&ds).unwrap();
        let mut expect = Tensor::zeros(&[16, 16]);
        crate::tensor::ops::axpy(&mut expect, -0.1, &delta);
        assert!(w.allclose(&expect, 1e-6));
    }

    #[test]
    fn nnz_bytes_independent_of_d() {
        // The paper's key memory claim: GPU memory is O((m+n) r), not O(d^2).
        let mut rng = Rng::new(3);
        let small = SparseProjector::init(256, 16, 4, &mut rng);
        let large = SparseProjector::init(256, 128, 4, &mut rng);
        assert_eq!(small.nnz_bytes(), large.nnz_bytes());
    }
}
