#!/usr/bin/env python3
"""Validate an exported Chrome trace-event file's structural invariants.

Usage: check_trace.py TRACE.json [--require-runtime] [--require-sim]
                                 [--require-tenants K]

Checks (all stdlib, no Perfetto needed):
  * the file is valid JSON with a `traceEvents` array and an `otherData`
    footer naming the clock source;
  * every `B` span open has a matching same-name `E` close on the same
    `(pid, tid)` track, properly nested (the one-writer-per-track
    invariant of `rust/src/trace/`);
  * timestamps are non-decreasing within every `(pid, tid)` track (both
    clock sources are monotone, so a violation means interleaved writers
    or a reordered export);
  * instant events carry a scope (`s`), counter events carry args;
  * `dropped_events` in the footer is reported (non-zero is a warning,
    not a failure — the recorder's capacity bound is a documented cap).

--require-runtime additionally fails unless at least one runtime track
(pid 1-5) recorded an event; --require-sim does the same for the
sim-prediction overlay (pid 10).  `simulate --trace-out` files are
sim-only; `train --trace-out` files have runtime tracks and, for policies
the DES models, the overlay too.

--require-tenants K fails unless the runtime tracks fan out over at
least K distinct tids: multi-tenant runs (`train --tenants K`) lay each
tenant's events on tid = tenant id (named `tenant<t>` via thread_name
metadata), so a K-tenant trace must show >= K runtime lanes.  Per-tenant
tids are ordinary tracks to every other check — nesting and timestamp
monotonicity are enforced per (pid, tid) as usual.
"""

import json
import sys

RUNTIME_PIDS = {1, 2, 3, 4, 5}
SIM_PID = 10


def fail(msg):
    print("check-trace: FAIL — %s" % msg)
    return 1


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip())
        return 2
    path = argv[1]
    require_runtime = "--require-runtime" in argv[2:]
    require_sim = "--require-sim" in argv[2:]
    require_tenants = 0
    if "--require-tenants" in argv[2:]:
        i = argv.index("--require-tenants")
        if i + 1 >= len(argv):
            return fail("--require-tenants needs a count")
        try:
            require_tenants = int(argv[i + 1])
        except ValueError:
            return fail("--require-tenants %r is not an integer" % argv[i + 1])
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return fail("cannot parse %s: %s" % (path, e))
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return fail("missing or empty traceEvents array")
    other = doc.get("otherData", {})
    clock = other.get("clock")
    if clock not in ("virtual", "real", "disabled"):
        return fail("otherData.clock is %r, want virtual/real/disabled" % clock)

    stacks = {}  # (pid, tid) -> list of open span names
    last_ts = {}  # (pid, tid) -> last timestamp seen
    counts = {"B": 0, "E": 0, "i": 0, "C": 0, "M": 0}
    pids = set()
    runtime_tids = set()  # tids seen on runtime pids (tenant lanes)
    for n, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in counts:
            return fail("event %d: unknown phase %r" % (n, ph))
        counts[ph] += 1
        pid, tid = ev.get("pid"), ev.get("tid")
        if not isinstance(pid, (int, float)) or not isinstance(tid, (int, float)):
            return fail("event %d: missing pid/tid" % n)
        key = (int(pid), int(tid))
        pids.add(key[0])
        if key[0] in RUNTIME_PIDS:
            runtime_tids.add(key[1])
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            return fail("event %d: missing ts" % n)
        if ts < last_ts.get(key, float("-inf")):
            return fail(
                "event %d (%s %r): ts %.3f < previous %.3f on track %s"
                % (n, ph, ev.get("name"), ts, last_ts[key], key)
            )
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get(key, [])
            if not stack:
                return fail("event %d: E %r with no open span on track %s" % (n, ev.get("name"), key))
            # Chrome E events may omit the name; when present it must
            # match the innermost open span (proper nesting).
            name = ev.get("name")
            opened = stack.pop()
            if name is not None and name != opened:
                return fail(
                    "event %d: E %r closes span %r on track %s (improper nesting)"
                    % (n, name, opened, key)
                )
        elif ph == "i":
            if ev.get("s") not in ("t", "p", "g"):
                return fail("event %d: instant %r lacks a scope" % (n, ev.get("name")))
        elif ph == "C":
            if not isinstance(ev.get("args"), dict) or not ev["args"]:
                return fail("event %d: counter %r has no args" % (n, ev.get("name")))
    unclosed = {k: v for k, v in stacks.items() if v}
    if unclosed:
        return fail("unclosed span(s) at end of trace: %s" % unclosed)
    if require_runtime and not (pids & RUNTIME_PIDS):
        return fail("no runtime-track (pid 1-5) events, --require-runtime set")
    if require_sim and SIM_PID not in pids:
        return fail("no sim-overlay (pid 10) events, --require-sim set")
    if require_tenants and len(runtime_tids) < require_tenants:
        return fail(
            "runtime tracks span %d tid(s) %s, --require-tenants %d set"
            % (len(runtime_tids), sorted(runtime_tids), require_tenants)
        )
    dropped = other.get("dropped_events", 0)
    if dropped:
        print("check-trace: WARNING — %s events dropped at the capacity bound" % dropped)
    print(
        "check-trace: OK — %d events (%d B/%d E spans, %d instants, %d counters, "
        "%d meta) on %d process track(s), clock=%s"
        % (
            len(events),
            counts["B"],
            counts["E"],
            counts["i"],
            counts["C"],
            counts["M"],
            len(pids),
            clock,
        )
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
