#!/usr/bin/env bash
# Tier-1 entry point: build + tests + smoke bench + perf/lint gates.
#
#   scripts/check.sh            # full tier-1 gate
#   scripts/check.sh --bench    # additionally run the full (non-smoke) bench
#
# The smoke bench keeps a small budget (~seconds) and writes
# BENCH_hotpath.smoke.json; only the full bench (here via --bench, or
# `cargo bench --bench hotpath` directly) writes the cross-PR trajectory
# file BENCH_hotpath.json at the repo root.
#
# Gates before build: the link-path real-sleep grep and the config-flag
# documentation gate (every flag parsed in src/config/mod.rs must appear
# as --<flag> in EXPERIMENTS.md).  After build: `cargo doc --no-deps`
# under RUSTDOCFLAGS="-D warnings" (broken intra-doc links fail).
#
# Gates after build/test:
#   * Perf: scripts/bench_compare.py fails the run when any (name, shape,
#     impl) row shared between the smoke output and the committed
#     BENCH_hotpath.json regressed by more than BENCH_GATE_PCT (default
#     25%).  The row set includes the wire-codec encode/decode throughputs
#     (codec_encode/codec_decode per format — the link hot path) and the
#     SIMD-vs-scalar / packed-vs-unpacked GEMM rows.  The gate is LIVE:
#     when the trajectory file is missing or still the empty sentinel, a
#     full bench run is recorded first and then judged against, so the
#     gate never silently skips; BENCH_SKIP_GATE=1 skips it explicitly.
#   * Lint: `cargo fmt --check` and `cargo clippy --all-targets -- -D
#     warnings`.  Failures are fatal with CHECK_STRICT=1 and loud warnings
#     otherwise (escape hatch until the tree is verified lint-clean on a
#     machine that has the rustfmt/clippy components installed).

set -euo pipefail
cd "$(dirname "$0")/../rust"
ROOT="$(cd .. && pwd)"

# Tier-1 tests must never sleep-and-assert around the link path: the
# timing-sensitive suite runs on the virtual link clock (LinkClock::Virtual
# + LinkLedger condvar sync), which is deterministic and takes milliseconds.
# The gate greps the integration tests and the comm.rs unit-test module for
# real sleeps; the Link's own Real-clock sleep (the bandwidth emulation
# itself, outside #[cfg(test)]) is exempt by construction.
echo "== link-path real-sleep gate =="
sleep_hits="$(grep -n "thread::sleep" tests/*.rs 2>/dev/null || true)"
comm_test_hits="$(awk '/#\[cfg\(test\)\]/{t=1} t && /thread::sleep/ {print FILENAME ":" FNR ": " $0}' \
    src/coordinator/comm.rs || true)"
if [[ -n "$sleep_hits$comm_test_hits" ]]; then
    echo "FAIL: real sleep on the link-path test set — use LinkClock::Virtual + LinkLedger::wait_len"
    [[ -n "$sleep_hits" ]] && echo "$sleep_hits"
    [[ -n "$comm_test_hits" ]] && echo "$comm_test_hits"
    exit 1
fi
echo "   clean"

# Every CLI flag parsed by the config system must be documented in the
# EXPERIMENTS.md reference (defaults/ranges/guidance) — docs rot is a
# gate failure, not a review nit.
echo "== config-flag documentation gate =="
missing_flags=""
for flag in $(grep -oE 'args\.get[a-z0-9_]*\("[a-z0-9-]+"\)' src/config/mod.rs \
    | sed -E 's/.*\("([^"]+)"\).*/\1/' | sort -u); do
    if ! grep -q -- "--$flag" "$ROOT/EXPERIMENTS.md"; then
        missing_flags="$missing_flags --$flag"
    fi
done
if [[ -n "$missing_flags" ]]; then
    echo "FAIL: flags parsed in src/config/mod.rs but undocumented in EXPERIMENTS.md:$missing_flags"
    exit 1
fi
echo "   clean"

# The coordinator hot path must not be able to panic: every lock uses
# fault::lock_recover, every failure routes through PipelineError /
# PipelineHealth.  The gate scans the non-test portion (everything before
# the first #[cfg(test)]) of the hot-path modules for unwrap/expect/panic!;
# the few intentional sites (thread spawn, injected test panics) carry a
# `gate: allow-panic` marker on the same or the preceding line.
echo "== coordinator no-panic gate =="
panic_hits=""
for f in src/coordinator/comm.rs src/coordinator/pipeline.rs \
         src/coordinator/worker.rs src/coordinator/projector_mgr.rs \
         src/coordinator/arbiter.rs src/coordinator/infer.rs \
         src/coordinator/kv.rs; do
    hits="$(awk '
        /#\[cfg\(test\)\]/ { exit }
        /\.unwrap\(\)|\.expect\(|panic!/ {
            if (index($0, "gate: allow-panic") == 0 && index(prev, "gate: allow-panic") == 0)
                print FILENAME ":" FNR ": " $0
        }
        { prev = $0 }' "$f" || true)"
    [[ -n "$hits" ]] && panic_hits="$panic_hits$hits"$'\n'
done
if [[ -n "${panic_hits//[$'\n']/}" ]]; then
    echo "FAIL: panic-capable call on the coordinator hot path — use fault::lock_recover /"
    echo "      PipelineError (or mark an intentional site with 'gate: allow-panic'):"
    echo "$panic_hits"
    exit 1
fi
echo "   clean"

echo "== cargo build --release =="
cargo build --release

# Broken intra-doc links (or any rustdoc warning) fail the gate: the
# module docs are the architecture documentation's source of truth.
echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# Timing-sensitive tests default to the deterministic virtual clock (the
# trainer's Auto mode consults LSP_LINK_CLOCK); export LSP_LINK_CLOCK=real
# to exercise the sleeping bandwidth emulation instead.
echo "== cargo test -q (LSP_LINK_CLOCK=${LSP_LINK_CLOCK:-virtual}) =="
LSP_LINK_CLOCK="${LSP_LINK_CLOCK:-virtual}" cargo test -q

# The scalar-fallback lane: LSP_FORCE_SCALAR=1 disables the AVX2 dispatch
# process-wide, so the SIMD-parity and kernel suites re-run against the
# pure scalar micro-kernels — CI covers the fallback even on AVX2 hosts.
echo "== scalar-fallback lane (LSP_FORCE_SCALAR=1, kernel/optim/codec libs) =="
LSP_FORCE_SCALAR=1 LSP_LINK_CLOCK=virtual cargo test -q --lib -- tensor:: optim:: codec::

# The fault-injection chaos suite always runs on the virtual clock, even
# when LSP_LINK_CLOCK=real above: injected stalls and retransmit backoff
# are charged to the clock, so under `real` the plans would sleep them out.
echo "== fault-injection chaos suite (LSP_LINK_CLOCK=virtual) =="
LSP_LINK_CLOCK=virtual cargo test -q --test faults

# The multi-tenant arbiter suite likewise always runs on the virtual
# clock: DRR interleaving, per-tenant fault isolation and the
# solo-equivalence invariant are deterministic there (and the blocking
# pops would sleep out retransmit backoff under `real`).
echo "== multi-tenant arbiter suite (LSP_LINK_CLOCK=virtual) =="
LSP_LINK_CLOCK=virtual cargo test -q --test tenancy

# The serving suite likewise pins the virtual clock: report byte-
# determinism, KV spill/restore exactness, the continuous-batching
# ordering property and the sim-agreement bounds are all exact there.
echo "== inference serving suite (LSP_LINK_CLOCK=virtual) =="
LSP_LINK_CLOCK=virtual cargo test -q --test infer

# Opt-in artifact enforcement: CHECK_ARTIFACTS=1 re-runs the
# artifact-gated suites with LSP_REQUIRE_ARTIFACTS=1, turning their
# graceful artifact-missing skips into hard failures — use it on machines
# where `make artifacts` is expected to have run.
if [[ "${CHECK_ARTIFACTS:-0}" == "1" ]]; then
    echo "== artifact-gated suites (LSP_REQUIRE_ARTIFACTS=1) =="
    LSP_REQUIRE_ARTIFACTS=1 LSP_LINK_CLOCK=virtual cargo test -q \
        --test policy_parity --test chunking --test tenancy --test faults --test infer
fi

echo "== cargo bench --bench hotpath -- smoke =="
# Remove any previous smoke output first: the bench falls back to writing
# into rust/ when the repo root is unwritable, and the gate must never
# judge a stale root-level file from an earlier run.
rm -f "$ROOT/BENCH_hotpath.smoke.json"
cargo bench --bench hotpath -- smoke

echo "== kernel-profile round-trip smoke =="
# The committed sample profile must survive config load -> KernelConfig ->
# a kernel run (the `tune` output contract).
profile_out="$(./target/release/lsp_offload tune --verify-profile "$ROOT/KERNEL_PROFILE.sample.json")"
echo "$profile_out"
if ! grep -q "profile-ok" <<<"$profile_out"; then
    echo "FAIL: tune --verify-profile did not print profile-ok for KERNEL_PROFILE.sample.json"
    exit 1
fi

echo "== trace schema gate (simulate --trace-out + scripts/check_trace.py) =="
# Artifact-free: export the DES's predicted lsp timeline as a Chrome trace
# and validate the structural invariants (valid JSON, balanced B/E spans
# per (pid, tid), monotone per-track timestamps).  A traced virtual-clock
# training run exercises the runtime tracks too, but needs artifacts —
# the byte-determinism and fault-coordinate contracts are pinned
# artifact-free by tests/tracing.rs above.
trace_tmp="$(mktemp "${TMPDIR:-/tmp}/lsp_trace_gate.XXXXXX.json")"
./target/release/lsp_offload simulate --schedule lsp --trace-out "$trace_tmp" >/dev/null
# Multi-tenant overlay: the DES's K-replica schedule must export a valid
# trace too (per-tenant task prefixes are ordinary span names to the
# checker; per-tenant runtime tids are covered by tests/tracing.rs and
# check_trace.py --require-tenants on traced `train --tenants` runs).
trace_tmp_mt="$(mktemp "${TMPDIR:-/tmp}/lsp_trace_gate_mt.XXXXXX.json")"
./target/release/lsp_offload simulate --schedule multi-tenant --tenants 3 \
    --trace-out "$trace_tmp_mt" >/dev/null
if ! command -v python3 >/dev/null 2>&1; then
    echo "   schema check skipped: python3 not available"
else
    python3 "$ROOT/scripts/check_trace.py" "$trace_tmp" --require-sim
    python3 "$ROOT/scripts/check_trace.py" "$trace_tmp_mt" --require-sim
fi
rm -f "$trace_tmp" "$trace_tmp_mt"

echo "== infer serve smoke (virtual clock, trace schema) =="
# Artifact-free runtime lane: serve a tiny synthetic model over the real
# virtual-clock links, require the greppable infer-ok line with tokens >
# 0, and validate the recorded trace's runtime tracks (admit/complete
# instants, per-chunk transfers, KV spill/restore events).
infer_trace="$(mktemp "${TMPDIR:-/tmp}/lsp_infer_smoke.XXXXXX.json")"
infer_out="$(LSP_LINK_CLOCK=virtual ./target/release/lsp_offload serve \
    --layers 6 --params-per-layer 4096 --requests 3 --gen-tokens 4 \
    --prefetch-depth 2 --kv-budget 8 --trace-out "$infer_trace")"
echo "$infer_out" | tail -n 2
infer_tokens="$(grep -oE 'infer-ok tokens=[0-9]+' <<<"$infer_out" | grep -oE '[0-9]+' || true)"
if [[ -z "$infer_tokens" || "$infer_tokens" -eq 0 ]]; then
    echo "FAIL: serve smoke did not print infer-ok with tokens > 0"
    exit 1
fi
if command -v python3 >/dev/null 2>&1; then
    python3 "$ROOT/scripts/check_trace.py" "$infer_trace" --require-runtime
else
    echo "   trace schema check skipped: python3 not available"
fi
rm -f "$infer_trace"

echo "== bench trajectory gate (>${BENCH_GATE_PCT:-25}% = fail) =="
# Live gate: an absent trajectory — or the committed empty sentinel (no
# measured rows yet) — triggers ONE full bench recording on this machine,
# after which the smoke rows are judged against it.  No dormant skip.
if [[ "${BENCH_SKIP_GATE:-0}" != "1" ]] && ! grep -q '"secs_min"' "$ROOT/BENCH_hotpath.json" 2>/dev/null; then
    echo "   trajectory missing or empty sentinel: recording a full bench run first"
    cargo bench --bench hotpath
fi
if [[ "${BENCH_SKIP_GATE:-0}" == "1" ]]; then
    echo "   skipped (BENCH_SKIP_GATE=1)"
elif ! grep -q '"secs_min"' "$ROOT/BENCH_hotpath.json" 2>/dev/null; then
    echo "FAIL: full bench run did not record measured rows in $ROOT/BENCH_hotpath.json"
    exit 1
elif [[ ! -f "$ROOT/BENCH_hotpath.smoke.json" ]]; then
    echo "   skipped: smoke bench wrote no $ROOT/BENCH_hotpath.smoke.json"
elif ! command -v python3 >/dev/null 2>&1; then
    echo "   skipped: python3 not available"
else
    python3 "$ROOT/scripts/bench_compare.py" \
        "$ROOT/BENCH_hotpath.json" "$ROOT/BENCH_hotpath.smoke.json" \
        "${BENCH_GATE_PCT:-25}"
fi

lint_fail=0
echo "== cargo fmt --check =="
if ! cargo fmt --version >/dev/null 2>&1; then
    # Component absence is an environment gap, not a lint finding — never
    # fail the gate (even strict) over a missing rustfmt/clippy install.
    echo "   skipped: rustfmt component not installed"
elif ! cargo fmt --check; then
    lint_fail=1
fi
echo "== cargo clippy --all-targets -- -D warnings =="
if ! cargo clippy --version >/dev/null 2>&1; then
    echo "   skipped: clippy component not installed"
elif ! cargo clippy --all-targets -- -D warnings; then
    lint_fail=1
fi
if [[ "$lint_fail" == 1 ]]; then
    if [[ "${CHECK_STRICT:-0}" == "1" ]]; then
        echo "FAIL: lint gates (fmt/clippy) failed under CHECK_STRICT=1"
        exit 1
    fi
    echo "WARNING: lint gates (fmt/clippy) failed; set CHECK_STRICT=1 to make this fatal"
fi

if [[ "${1:-}" == "--bench" ]]; then
    echo "== cargo bench --bench hotpath (full) =="
    cargo bench --bench hotpath
fi

echo "OK"
