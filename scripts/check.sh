#!/usr/bin/env bash
# Tier-1 entry point: build + tests + a smoke pass of the hot-path bench.
#
#   scripts/check.sh            # full tier-1 gate
#   scripts/check.sh --bench    # additionally run the full (non-smoke) bench
#
# The smoke bench keeps a small budget (~seconds) and writes
# BENCH_hotpath.smoke.json; only the full bench (here via --bench, or
# `cargo bench --bench hotpath` directly) writes the cross-PR trajectory
# file BENCH_hotpath.json at the repo root.

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --bench hotpath -- smoke =="
cargo bench --bench hotpath -- smoke

if [[ "${1:-}" == "--bench" ]]; then
    echo "== cargo bench --bench hotpath (full) =="
    cargo bench --bench hotpath
fi

echo "OK"
