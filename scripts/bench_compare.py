#!/usr/bin/env python3
"""Gate the hot-path bench against the committed trajectory.

Usage: bench_compare.py TRAJECTORY.json SMOKE.json [max_regression_pct]

Compares `secs_min` for every (name, shape, impl) row present in BOTH
files and exits non-zero if any row is slower than the trajectory by more
than the threshold (default 25%).  Rows unique to either file are ignored
(smoke runs use a reduced shape set), as are rows whose smoke run managed
fewer than MIN_ITERS iterations — a min over 1-2 samples is biased high
and would fail spuriously on a loaded machine.  Faster-than-trajectory
rows always pass — this is a regression gate, not a reproducibility check —
and are listed in an improvements table (with per-row GFLOP/s deltas where
both sides report `gops`) so perf wins are visible in the gate output, not
just regressions.
"""

import json
import sys

# Minimum smoke-side sample count for a row to be judged at all.
MIN_ITERS = 3


def rows(path):
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for r in doc.get("results", []):
        out[(r.get("name"), r.get("shape"), r.get("impl"))] = r
    return out


def main(argv):
    if len(argv) < 3:
        print(__doc__.strip())
        return 2
    base = rows(argv[1])
    cur = rows(argv[2])
    pct = float(argv[3]) if len(argv) > 3 else 25.0
    shared = sorted(set(base) & set(cur))
    if not shared:
        print("bench-compare: no matching (name, shape, impl) rows; nothing to gate")
        return 0
    bad = []
    improved = []
    judged = 0
    unjudgeable = 0
    for key in shared:
        b = base[key].get("secs_min", 0.0)
        c = cur[key].get("secs_min", 0.0)
        if not b or b <= 0.0 or not c or c <= 0.0:
            # No silent caps: a malformed row on either side is reported,
            # not dropped from the listing (a zero smoke-side time would
            # otherwise pass as a -100% 'improvement').
            print(
                "  %-18s %-26s %-14s base %-10r cur %-10r skip (unjudgeable secs_min)"
                % (key[0], key[1], key[2], b, c)
            )
            unjudgeable += 1
            continue
        delta = (c - b) / b * 100.0
        # Rows the smoke budget could not sample enough are reported but
        # never gated (old trajectory files without "iters" are judged).
        iters = cur[key].get("iters", MIN_ITERS)
        noisy = iters < MIN_ITERS
        if noisy:
            flag = "skip (only %d iters)" % iters
        elif delta > pct:
            flag = "REGRESSION"
        else:
            flag = "ok"
        # Per-row throughput delta where both sides report gops
        # (GFLOP/s for the kernels, GB/s for the codecs).
        gb, gc = base[key].get("gops"), cur[key].get("gops")
        gtxt = ""
        if gb and gc:
            gtxt = "  %7.2f -> %7.2f Gop/s (%+.1f%%)" % (gb, gc, (gc - gb) / gb * 100.0)
        print(
            "  %-18s %-26s %-14s base %.3es  cur %.3es  %+7.1f%%  %s%s"
            % (key[0], key[1], key[2], b, c, delta, flag, gtxt)
        )
        if noisy:
            continue
        judged += 1
        if delta > pct:
            bad.append(key)
        elif delta < 0.0:
            improved.append((delta, key, gb, gc))
    if improved:
        improved.sort()
        print("bench-compare: %d row(s) improved vs the trajectory:" % len(improved))
        for delta, key, gb, gc in improved:
            gtxt = ""
            if gb and gc:
                gtxt = "  %7.2f -> %7.2f Gop/s" % (gb, gc)
            print(
                "  %-18s %-26s %-14s %+7.1f%%%s"
                % (key[0], key[1], key[2], delta, gtxt)
            )
    if bad:
        print(
            "bench-compare: FAIL — %d row(s) regressed more than %.0f%% "
            "vs the trajectory" % (len(bad), pct)
        )
        return 1
    print(
        "bench-compare: OK — %d judged row(s) within %.0f%% "
        "(%d improved, %d skipped as noisy, %d unjudgeable)"
        % (judged, pct, len(improved), len(shared) - judged - unjudgeable, unjudgeable)
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
