"""L2 model correctness: per-layer entries compose to the monolithic
train_step, gradients match autodiff, and shapes/param counts line up."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_head=2, d_ff=64,
                    n_layer=2, seq=16, batch=2, r=2)


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    params = {"wte": rng.standard_normal((cfg.vocab, cfg.d_model)) * 0.05,
              "wpe": rng.standard_normal((cfg.seq, cfg.d_model)) * 0.05}
    blocks = []
    for _ in range(cfg.n_layer):
        blk = []
        for name, shape in M.block_param_specs(cfg):
            if name.endswith("_g"):
                blk.append(np.ones(shape))
            elif name.startswith("b_") or name.endswith("_b"):
                blk.append(np.zeros(shape))
            else:
                blk.append(rng.standard_normal(shape) * 0.05)
        blocks.append([jnp.asarray(a, jnp.float32) for a in blk])
    params = {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
    lnf_g = jnp.ones((cfg.d_model,), jnp.float32)
    lnf_b = jnp.zeros((cfg.d_model,), jnp.float32)
    return params, blocks, lnf_g, lnf_b


def batch(cfg, seed=1):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32)
    tgts = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32)
    return jnp.asarray(toks), jnp.asarray(tgts)


def test_param_count_formula():
    n = M.n_params(CFG)
    per_block = 32 * 2 + (32 * 96 + 96) + (32 * 32 + 32) + 32 * 2 \
        + (32 * 64 + 64) + (64 * 32 + 32)
    assert n == 64 * 32 + 16 * 32 + 2 * per_block + 2 * 32


def test_block_fwd_shapes_and_residual():
    params, blocks, _, _ = init_params(CFG)
    h = jnp.asarray(np.random.default_rng(2).standard_normal(
        (CFG.batch, CFG.seq, CFG.d_model)).astype(np.float32))
    out = M.block_fwd(h, *blocks[0], n_head=CFG.n_head)[0]
    assert out.shape == h.shape
    # With zero weights the block is an identity (residual path only).
    zero_blk = [jnp.zeros_like(p) if p.ndim == 2 else p for p in blocks[0]]
    out0 = M.block_fwd(h, *zero_blk, n_head=CFG.n_head)[0]
    # attention with zero qkv -> av=0, mlp zero -> identity
    np.testing.assert_allclose(np.asarray(out0), np.asarray(h), atol=1e-5)


def test_per_layer_composition_matches_train_step():
    params, blocks, lnf_g, lnf_b = init_params(CFG)
    toks, tgts = batch(CFG)

    h = M.embed_fwd(toks, params["wte"], params["wpe"])[0]
    h_ins = []
    for blk in blocks:
        h_ins.append(h)
        h = M.block_fwd(h, *blk, n_head=CFG.n_head)[0]
    loss_layered = M.head_loss_fwd(h, lnf_g, lnf_b, params["wte"], tgts)[0]

    flat = [params["wte"], params["wpe"]]
    for blk in blocks:
        flat += blk
    flat += [lnf_g, lnf_b]
    outs = M.train_step(toks, tgts, *flat, cfg=CFG)
    loss_mono = outs[0]
    np.testing.assert_allclose(np.asarray(loss_layered), np.asarray(loss_mono),
                               rtol=1e-5, atol=1e-5)


def test_block_bwd_matches_autodiff():
    _, blocks, _, _ = init_params(CFG)
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.standard_normal(
        (CFG.batch, CFG.seq, CFG.d_model)).astype(np.float32))
    d_out = jnp.asarray(rng.standard_normal(h.shape).astype(np.float32))

    outs = M.block_bwd(h, *blocks[0], d_out, n_head=CFG.n_head)
    d_in = outs[0]

    fn = lambda h, ps: M.block_fwd(h, *ps, n_head=CFG.n_head)[0]
    _, vjp = jax.vjp(fn, h, tuple(blocks[0]))
    want_d_in, want_d_ps = vjp(d_out)
    np.testing.assert_allclose(np.asarray(d_in), np.asarray(want_d_in),
                               rtol=1e-4, atol=1e-4)
    for got, want in zip(outs[1:], want_d_ps):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_head_loss_bwd_grad_is_correct():
    params, _, lnf_g, lnf_b = init_params(CFG)
    toks, tgts = batch(CFG)
    rng = np.random.default_rng(4)
    h = jnp.asarray(rng.standard_normal(
        (CFG.batch, CFG.seq, CFG.d_model)).astype(np.float32))
    outs = M.head_loss_bwd(h, lnf_g, lnf_b, params["wte"], tgts)
    loss, d_h = outs[0], outs[1]
    fn = lambda h: M.head_loss_fwd(h, lnf_g, lnf_b, params["wte"], tgts)[0].reshape(())
    want = jax.grad(fn)(h)
    np.testing.assert_allclose(np.asarray(d_h), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(loss[0, 0]) > 0


def test_embed_bwd_scatter():
    toks = jnp.asarray([[1, 1, 2]], jnp.int32)
    d_h = jnp.ones((1, 3, 4), jnp.float32)
    d_wte, d_wpe = M.embed_bwd(toks, d_h, vocab=8)
    assert d_wte.shape == (8, 4)
    np.testing.assert_allclose(np.asarray(d_wte[1]), 2 * np.ones(4))
    np.testing.assert_allclose(np.asarray(d_wte[2]), np.ones(4))
    np.testing.assert_allclose(np.asarray(d_wte[0]), np.zeros(4))
    np.testing.assert_allclose(np.asarray(d_wpe), np.ones((3, 4)))


def test_loss_at_init_near_uniform():
    params, blocks, lnf_g, lnf_b = init_params(CFG)
    toks, tgts = batch(CFG)
    h = M.embed_fwd(toks, params["wte"], params["wpe"])[0]
    for blk in blocks:
        h = M.block_fwd(h, *blk, n_head=CFG.n_head)[0]
    loss = float(M.head_loss_fwd(h, lnf_g, lnf_b, params["wte"], tgts)[0][0, 0])
    assert abs(loss - np.log(CFG.vocab)) < 0.5, loss


def test_subspace_sizes():
    assert CFG.subspace("qkv") == 16
    assert CFG.subspace("attn_o") == 16
    assert CFG.subspace("fc") == 16
    assert CFG.subspace("proj") == 16
    assert CFG.kind_dims("qkv") == (32, 96)
    assert CFG.kind_dims("proj") == (64, 32)


def test_pallas_attention_path(monkeypatch):
    """The model works with the Pallas flash-attention fwd as well."""
    monkeypatch.setenv("LSP_ATTN", "pallas")
    _, blocks, _, _ = init_params(CFG)
    h = jnp.asarray(np.random.default_rng(5).standard_normal(
        (CFG.batch, CFG.seq, CFG.d_model)).astype(np.float32))
    out_pallas = M.block_fwd(h, *blocks[0], n_head=CFG.n_head)[0]
    monkeypatch.setenv("LSP_ATTN", "ref")
    out_ref = M.block_fwd(h, *blocks[0], n_head=CFG.n_head)[0]
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)
