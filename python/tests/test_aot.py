"""AOT pipeline tests: lowering produces parseable HLO text with the
shapes the manifest promises, for the tiny preset."""

import json
import os
import re
import subprocess
import sys

import pytest

from compile import aot, model as M

pytestmark = pytest.mark.filterwarnings("ignore")


@pytest.fixture(scope="module")
def tiny_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("art_tiny")
    aot.build(aot.PRESETS["tiny"], str(out), monolith=True, preset="tiny")
    return out


def test_manifest_schema(tiny_dir):
    man = json.loads((tiny_dir / "manifest.json").read_text())
    assert man["preset"] == "tiny"
    cfg = man["config"]
    assert cfg["d_model"] == 32 and cfg["n_layer"] == 2
    assert cfg["n_params"] == M.n_params(aot.PRESETS["tiny"])
    assert set(man["kinds"]) == {"qkv", "attn_o", "fc", "proj"}
    names = {e["name"] for e in man["entries"]}
    for required in ["embed_fwd", "block_fwd", "block_bwd", "head_loss_fwd",
                     "head_loss_bwd", "embed_bwd", "train_step",
                     "compress_qkv", "apply_fc", "bias_proj", "learn_attn_o",
                     "adam_sub_qkv", "state_proj_fc"]:
        assert required in names, required
    # Every entry's file exists and is non-trivial HLO text.
    for e in man["entries"]:
        text = (tiny_dir / e["file"]).read_text()
        assert "ENTRY" in text and "parameter(0)" in text, e["name"]


def test_hlo_parameter_counts_match_manifest(tiny_dir):
    man = json.loads((tiny_dir / "manifest.json").read_text())
    for e in man["entries"]:
        text = (tiny_dir / e["file"]).read_text()
        entry_body = text[text.index("ENTRY"):]
        params = set(re.findall(r"parameter\((\d+)\)", entry_body))
        assert len(params) == len(e["args"]), \
            f"{e['name']}: HLO has {len(params)} params, manifest {len(e['args'])}"


def test_tuple_out_flags(tiny_dir):
    man = json.loads((tiny_dir / "manifest.json").read_text())
    by_name = {e["name"]: e for e in man["entries"]}
    assert not by_name["block_fwd"]["tuple_out"]
    assert not by_name["compress_qkv"]["tuple_out"]
    assert by_name["block_bwd"]["tuple_out"]
    assert by_name["train_step"]["tuple_out"]
    # Single-output entries have exactly one out; block_bwd has 1 + 12.
    assert len(by_name["block_fwd"]["outs"]) == 1
    assert len(by_name["block_bwd"]["outs"]) == 13


def test_gather_lens_are_static(tiny_dir):
    man = json.loads((tiny_dir / "manifest.json").read_text())
    for kind, km in man["kinds"].items():
        import math
        assert km["lp"] == km["r"] * math.ceil(km["m"] / km["d"]), kind
        assert km["lq"] == km["r"] * math.ceil(km["n"] / km["d"]), kind


def test_cli_help_and_presets():
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--help"],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0
    for preset in aot.PRESETS:
        assert preset in out.stdout


def test_axpy_lens_cover_all_params(tiny_dir):
    man = json.loads((tiny_dir / "manifest.json").read_text())
    lens = set(man["axpy_lens"])
    cfg = man["config"]
    assert cfg["vocab"] * cfg["d_model"] in lens  # wte
    assert cfg["seq"] * cfg["d_model"] in lens    # wpe
    for bp in man["block_params"]:
        size = 1
        for s in bp["shape"]:
            size *= s
        assert size in lens, bp["name"]
