"""Projector learning (Eq. 3) and optimizer-state projection tests."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import proj_learn
from compile.kernels import formats, ref


def setup(m, n, d, r, seed):
    p_idx = jnp.asarray(formats.make_positions(m, d, r, seed))
    p_val = jnp.asarray(formats.init_values(m, r, seed + 1))
    q_idx = jnp.asarray(formats.make_positions(n, d, r, seed + 2))
    q_val = jnp.asarray(formats.init_values(n, r, seed + 3))
    return p_idx, p_val, q_idx, q_val


def run_learn(g, p_idx, p_val, q_idx, q_val, d, steps, lr=0.02):
    m, r = p_val.shape
    n = q_val.shape[0]
    mp = jnp.zeros((m, r)); vp = jnp.zeros((m, r))
    mq = jnp.zeros((n, r)); vq = jnp.zeros((n, r))
    bias = None
    for t in range(1, steps + 1):
        out = proj_learn.learn_step(
            g, p_idx, p_val, q_idx, q_val, mp, vp, mq, vq,
            jnp.full((1, 1), float(t)), jnp.full((1, 1), lr),
            d=d, beta=1e-4)
        p_val, q_val, mp, vp, mq, vq, bias = out
    return p_val, q_val, float(bias[0, 0])


def test_learning_reduces_bias_on_low_rank_gradient():
    m, n, d, r = 48, 56, 16, 2
    rng = np.random.default_rng(0)
    g = jnp.asarray((rng.standard_normal((m, 3)) @
                     rng.standard_normal((3, n))).astype(np.float32))
    p_idx, p_val, q_idx, q_val = setup(m, n, d, r, 5)
    bias0 = float(ref.bias_ref(g, p_idx, p_val, q_idx, q_val, d)[0][0, 0])
    _, _, bias_end = run_learn(g, p_idx, p_val, q_idx, q_val, d, steps=60)
    assert bias_end < bias0 * 0.8, (bias0, bias_end)


@given(seed=st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_learn_step_bias_output_matches_bias_ref(seed):
    m, n, d, r = 24, 20, 8, 2
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    p_idx, p_val, q_idx, q_val = setup(m, n, d, r, seed)
    out = proj_learn.learn_step(
        g, p_idx, p_val, q_idx, q_val,
        jnp.zeros((m, r)), jnp.zeros((m, r)),
        jnp.zeros((n, r)), jnp.zeros((n, r)),
        jnp.ones((1, 1)), jnp.full((1, 1), 0.01), d=d, beta=1e-4)
    # The reported bias is the *pre-update* bias.
    want = float(ref.bias_ref(g, p_idx, p_val, q_idx, q_val, d)[0][0, 0])
    np.testing.assert_allclose(float(out[6][0, 0]), want, rtol=1e-4)


def test_state_projection_identity_when_subspace_unchanged():
    """Projecting onto the same orthonormal-ish subspace should roughly
    preserve the moments; exactly identity when P^T P = I."""
    m, n, d, r = 16, 16, 16, 1
    # Identity projectors: idx = row index, val = 1.
    eye_idx = jnp.arange(m, dtype=jnp.int32).reshape(m, 1)
    ones = jnp.ones((m, 1), jnp.float32)
    ms = jnp.asarray(np.random.default_rng(1).standard_normal((d, d)).astype(np.float32))
    vs = jnp.abs(jnp.asarray(np.random.default_rng(2).standard_normal((d, d)).astype(np.float32)))
    out = proj_learn.project_state(
        ms, vs, eye_idx, ones, eye_idx, ones, eye_idx, ones, eye_idx, ones, d=d)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ms), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(vs), rtol=1e-4, atol=1e-5)


def test_state_projection_shapes_and_scale():
    m, n, d, r = 32, 24, 8, 2
    p_idx, p_val, q_idx, q_val = setup(m, n, d, r, 9)
    p2_idx, p2_val, q2_idx, q2_val = setup(m, n, d, r, 29)
    ms = jnp.ones((d, d), jnp.float32)
    vs = jnp.ones((d, d), jnp.float32)
    m2, v2 = proj_learn.project_state(
        ms, vs, p_idx, p_val, q_idx, q_val, p2_idx, p2_val, q2_idx, q2_val, d=d)
    assert m2.shape == (d, d) and v2.shape == (d, d)
    # V projection uses elementwise squares -> stays non-negative.
    assert float(jnp.min(v2)) >= 0.0
    assert np.isfinite(np.asarray(m2)).all()


def test_eq3_regularizer_term():
    m, n, d, r = 16, 16, 8, 2
    p_idx, p_val, q_idx, q_val = setup(m, n, d, r, 3)
    g = jnp.zeros((m, n), jnp.float32)
    # With G = 0, loss = beta * (||P|| + ||Q||) and bias = 0.
    loss, bias = proj_learn.eq3_loss(g, p_idx, p_val, q_idx, q_val, d, beta=0.5)
    assert float(bias) < 1e-6
    p = ref.densify(p_idx, p_val, d)
    q = ref.densify(q_idx, q_val, d)
    want = 0.5 * (float(jnp.linalg.norm(p)) + float(jnp.linalg.norm(q)))
    np.testing.assert_allclose(float(loss), want, rtol=1e-5)
