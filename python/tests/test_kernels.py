"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
with hypothesis sweeping shapes and magnitudes (the session's core
correctness signal for the compile path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import formats, ref
from compile.kernels.attention import flash_attention
from compile.kernels.fused_adam import fused_adam
from compile.kernels.lsp_decompress import lsp_apply
from compile.kernels.lsp_project import lsp_compress
from compile.kernels.tiled_matmul import tiled_matmul

SETTINGS = dict(max_examples=12, deadline=None)


def make_pair(m, n, d, r, seed):
    p_idx = formats.make_positions(m, d, r, seed)
    p_val = formats.init_values(m, r, seed + 1)
    q_idx = formats.make_positions(n, d, r, seed + 2)
    q_val = formats.init_values(n, r, seed + 3)
    return p_idx, p_val, q_idx, q_val


@given(
    m=st.integers(8, 96),
    n=st.integers(8, 96),
    d_pow=st.integers(2, 5),
    r=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_compress_matches_ref(m, n, d_pow, r, seed):
    d = 2**d_pow
    r = min(r, d)
    p_idx, p_val, q_idx, q_val = make_pair(m, n, d, r, seed)
    g = np.random.default_rng(seed).standard_normal((m, n)).astype(np.float32)

    want = ref.lsp_compress_ref(
        jnp.asarray(g), jnp.asarray(p_idx), jnp.asarray(p_val),
        jnp.asarray(q_idx), jnp.asarray(q_val), d)
    pg = formats.row_to_gather(p_idx, p_val, d)
    qg = formats.row_to_gather(q_idx, q_val, d)
    got = lsp_compress(jnp.asarray(g), jnp.asarray(pg[0]), jnp.asarray(pg[1]),
                       jnp.asarray(qg[0]), jnp.asarray(qg[1]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(8, 80),
    n=st.integers(8, 80),
    d_pow=st.integers(2, 5),
    r=st.integers(1, 4),
    lr=st.floats(1e-4, 1.0),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_apply_matches_ref(m, n, d_pow, r, lr, seed):
    d = 2**d_pow
    r = min(r, d)
    p_idx, p_val, q_idx, q_val = make_pair(m, n, d, r, seed)
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, n)).astype(np.float32)
    ds = rng.standard_normal((d, d)).astype(np.float32)

    want = ref.lsp_apply_ref(jnp.asarray(w), jnp.asarray(p_idx),
                             jnp.asarray(p_val), jnp.asarray(q_idx),
                             jnp.asarray(q_val), jnp.asarray(ds), lr)
    got = lsp_apply(jnp.asarray(w), jnp.asarray(p_idx), jnp.asarray(p_val),
                    jnp.asarray(q_idx), jnp.asarray(q_val), jnp.asarray(ds),
                    jnp.full((1, 1), lr, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@given(
    a=st.integers(1, 128),
    b=st.integers(1, 64),
    t=st.integers(1, 5),
    scale=st.floats(1e-3, 10.0),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_fused_adam_matches_ref(a, b, t, scale, seed):
    rng = np.random.default_rng(seed)
    g = (rng.standard_normal((a, b)) * scale).astype(np.float32)
    m = (rng.standard_normal((a, b)) * scale * 0.1).astype(np.float32)
    v = np.abs(rng.standard_normal((a, b)) * scale * 0.01).astype(np.float32)
    ts = jnp.full((1, 1), float(t), jnp.float32)
    want = ref.adam_ref(jnp.asarray(g), jnp.asarray(m), jnp.asarray(v), ts)
    got = fused_adam(jnp.asarray(g), jnp.asarray(m), jnp.asarray(v), ts)
    for w, o in zip(want, got):
        np.testing.assert_allclose(np.asarray(o), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(1, 100),
    k=st.integers(1, 100),
    n=st.integers(1, 100),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_tiled_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = tiled_matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-3, atol=1e-3)


@given(
    bsz=st.integers(1, 3),
    h=st.integers(1, 3),
    t_pow=st.integers(2, 6),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=8, deadline=None)
def test_flash_attention_matches_ref(bsz, h, t_pow, dh, seed):
    t = 2**t_pow
    rng = np.random.default_rng(seed)
    q = (rng.standard_normal((bsz, h, t, dh)) * 0.5).astype(np.float32)
    k = (rng.standard_normal((bsz, h, t, dh)) * 0.5).astype(np.float32)
    v = rng.standard_normal((bsz, h, t, dh)).astype(np.float32)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_grad_matches_ref():
    rng = np.random.default_rng(0)
    q = (rng.standard_normal((2, 2, 32, 16)) * 0.5).astype(np.float32)
    k = (rng.standard_normal((2, 2, 32, 16)) * 0.5).astype(np.float32)
    v = rng.standard_normal((2, 2, 32, 16)).astype(np.float32)
    f = lambda *a: (flash_attention(*a) ** 2).sum()
    fr = lambda *a: (ref.attention_ref(*a) ** 2).sum()
    g1 = jax.grad(f, argnums=(0, 1, 2))(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    g2 = jax.grad(fr, argnums=(0, 1, 2))(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_attention_causality():
    """Future tokens must not influence earlier outputs."""
    rng = np.random.default_rng(1)
    q = rng.standard_normal((1, 1, 16, 8)).astype(np.float32)
    k = rng.standard_normal((1, 1, 16, 8)).astype(np.float32)
    v = rng.standard_normal((1, 1, 16, 8)).astype(np.float32)
    out1 = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    k2, v2 = k.copy(), v.copy()
    k2[0, 0, -1] += 100.0
    v2[0, 0, -1] -= 50.0
    out2 = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2)))
    np.testing.assert_allclose(out1[0, 0, :-1], out2[0, 0, :-1], atol=1e-5)
    assert np.abs(out1[0, 0, -1] - out2[0, 0, -1]).max() > 1e-3


# ---------------------------------------------------------------------------
# Format invariants
# ---------------------------------------------------------------------------

@given(
    m=st.integers(4, 200),
    d_pow=st.integers(2, 6),
    r=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_balanced_positions_and_gather_roundtrip(m, d_pow, r, seed):
    d = 2**d_pow
    r = min(r, d)
    idx = formats.make_positions(m, d, r, seed)
    assert idx.shape == (m, r)
    assert idx.min() >= 0 and idx.max() < d
    # Exact balance: every column holds exactly L = r * ceil(m/d) entries.
    loads = np.bincount(idx.reshape(-1), minlength=d)
    assert loads.max() <= formats.gather_len(m, d, r)
    # Gather layout reconstructs the same dense matrix.
    val = formats.init_values(m, r, seed + 9)
    dense = formats.densify(idx, val, d)
    gidx, gval = formats.row_to_gather(idx, val, d)
    dense2 = np.zeros((m, d), np.float32)
    for j in range(d):
        for s in range(gidx.shape[1]):
            if gval[j, s] != 0.0:
                dense2[gidx[j, s], j] += gval[j, s]
    np.testing.assert_allclose(dense2, dense, atol=1e-6)


def test_jl_unbiasedness():
    """E[P P^T] ~ I scaling: random sparse projection preserves norms on
    average (the JL property motivating the init)."""
    m, d, r = 64, 256, 4
    rng = np.random.default_rng(0)
    x = rng.standard_normal(m).astype(np.float32)
    ratios = []
    for s in range(64):
        idx = formats.make_positions(m, d, r, s)
        val = formats.init_values(m, r, 1000 + s)
        p = formats.densify(idx, val, d)
        ratios.append(float(np.linalg.norm(p.T @ x) / np.linalg.norm(x)))
    mean = np.mean(ratios)
    assert 0.85 < mean < 1.15, f"JL norm preservation broken: {mean}"


def test_compress_rejects_bad_r():
    with pytest.raises(ValueError):
        formats.make_positions(8, 4, 5)
