"""Projector learning: fit the non-zero *values* of the (d, r)-sparse
projectors to a calibration gradient (paper Eq. 3).

    min_{P,Q}  ||P P^T G Q Q^T - G||_F  +  beta * (||P||_F + ||Q||_F)

Non-zero *positions* are fixed (sampled by the balanced construction in
formats.py / rust sparse::); only the values are trained, with Adam.  One
``learn_step`` call is one Adam step; the rust projector manager (Alg. 1
MAYBEUPDATE) iterates it until the relative bias drops below alpha or a
step budget ("Timeout") is exhausted, then projects the optimizer state onto
the new subspace (Alg. 1 lines 8-9).

All state (values + Adam moments) is threaded through arguments so the
artifact stays pure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref as kref

__all__ = ["learn_step", "project_state", "eq3_loss"]

_BETA1, _BETA2, _EPS = 0.9, 0.999, 1e-8


def eq3_loss(g, p_idx, p_val, q_idx, q_val, d: int, beta: float):
    p = kref.densify(p_idx, p_val, d)
    q = kref.densify(q_idx, q_val, d)
    est = p @ (p.T @ g @ q) @ q.T
    bias = jnp.linalg.norm(est - g)
    reg = jnp.linalg.norm(p) + jnp.linalg.norm(q)
    return bias + beta * reg, bias


def learn_step(g, p_idx, p_val, q_idx, q_val,
               mp, vp, mq, vq, t, lr, *, d: int, beta: float):
    """One Adam step on (p_val, q_val) against Eq. 3.

    Args:
      g:            f32[m, n] calibration gradient.
      p_idx/q_idx:  int32[m, r] / int32[n, r] fixed positions.
      p_val/q_val:  f32 values being learned.
      mp/vp/mq/vq:  Adam moments, same shapes as the values.
      t:            f32[1, 1] 1-based step.
      lr:           f32[1, 1] learning rate.
    Returns:
      (p_val', q_val', mp', vp', mq', vq', rel_bias[1,1])
    """

    def loss_fn(pv, qv):
        loss, bias = eq3_loss(g, p_idx, pv, q_idx, qv, d, beta)
        return loss, bias

    (_, bias), (gp, gq) = jax.value_and_grad(loss_fn, argnums=(0, 1),
                                             has_aux=True)(p_val, q_val)

    def adam(val, grad, m, v):
        ts = t.reshape(())
        m2 = _BETA1 * m + (1 - _BETA1) * grad
        v2 = _BETA2 * v + (1 - _BETA2) * grad * grad
        mhat = m2 / (1 - _BETA1 ** ts)
        vhat = v2 / (1 - _BETA2 ** ts)
        return val - lr.reshape(()) * mhat / (jnp.sqrt(vhat) + _EPS), m2, v2

    p2, mp2, vp2 = adam(p_val, gp, mp, vp)
    q2, mq2, vq2 = adam(q_val, gq, mq, vq)
    g_norm = jnp.maximum(jnp.linalg.norm(g), 1e-30)
    return (p2, q2, mp2, vp2, mq2, vq2, (bias / g_norm).reshape(1, 1))


def project_state(m_s, v_s, p_idx_old, p_val_old, q_idx_old, q_val_old,
                  p_idx_new, p_val_new, q_idx_new, q_val_new, *, d: int):
    """Project subspace Adam state onto a new subspace (Alg. 1 lines 8-9).

      M' = (P_new^T P_old) M (Q_old^T Q_new)
      V' = (P_new^T P_old)^2 V (Q_old^T Q_new)^2   (elementwise squares)
    """
    po = kref.densify(p_idx_old, p_val_old, d)
    qo = kref.densify(q_idx_old, q_val_old, d)
    pn = kref.densify(p_idx_new, p_val_new, d)
    qn = kref.densify(q_idx_new, q_val_new, d)
    tp = pn.T @ po  # [d, d]
    tq = qo.T @ qn  # [d, d]
    m2 = tp @ m_s @ tq
    v2 = (tp * tp) @ v_s @ (tq * tq)
    return (m2, v2)
