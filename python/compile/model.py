"""L2: GPT-style decoder model, exposed as per-layer AOT entry points.

The rust coordinator schedules fwd/bwd *layer by layer* (the paper's Alg. 3),
so instead of one monolithic train step we lower one executable per layer
*type* and reuse it across layers by passing that layer's weights as runtime
arguments:

  embed_fwd      tokens, wte, wpe                  -> h0
  block_fwd      h, <12 block params>              -> h_out
  block_bwd      h_in, <12 block params>, d_out    -> d_in, <12 grads>
                 (recomputes the forward inside jax.vjp = the paper's
                  gradient-checkpointing configuration)
  head_loss_fwd  h, lnf_g, lnf_b, wte, targets     -> loss            (eval)
  head_loss_bwd  h, lnf_g, lnf_b, wte, targets     -> loss, d_h, d_lnf_g,
                                                      d_lnf_b, d_wte
  embed_bwd      tokens, d_h0                      -> d_wte, d_wpe
  train_step     tokens, targets, <all params>     -> loss, <all grads>
                 (monolithic; the no-offload "native" baseline + parity tests)

Canonical per-block parameter order (index -> name), shared with the rust
side through manifest.json:

  0 ln1_g[D]  1 ln1_b[D]  2 w_qkv[D,3D]  3 b_qkv[3D]  4 w_o[D,D]  5 b_o[D]
  6 ln2_g[D]  7 ln2_b[D]  8 w_fc[D,F]    9 b_fc[F]   10 w_pr[F,D] 11 b_pr[D]

LSP projectors attach to the four matrices (2, 4, 8, 10), kinds
"qkv" / "attn_o" / "fc" / "proj".
"""

from __future__ import annotations

import dataclasses
import os
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.attention import flash_attention

__all__ = [
    "ModelConfig",
    "BLOCK_PARAM_NAMES",
    "LSP_KINDS",
    "block_param_specs",
    "embed_fwd",
    "block_fwd",
    "block_bwd",
    "head_loss_fwd",
    "head_loss_bwd",
    "embed_bwd",
    "train_step",
    "n_params",
]

BLOCK_PARAM_NAMES = (
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_o", "b_o",
    "ln2_g", "ln2_b", "w_fc", "b_fc", "w_pr", "b_pr",
)

# name -> (block param index, (m, n) as a function of (D, F))
LSP_KINDS = {
    "qkv": (2, lambda d, f: (d, 3 * d)),
    "attn_o": (4, lambda d, f: (d, d)),
    "fc": (8, lambda d, f: (d, f)),
    "proj": (10, lambda d, f: (f, d)),
}

_LN_EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model + training-shape configuration baked into the artifacts."""

    vocab: int
    d_model: int
    n_head: int
    d_ff: int
    n_layer: int
    seq: int
    batch: int
    # LSP hyperparameters (paper: d = n/2, small r such as 4 or 8)
    r: int = 4
    d_frac: float = 0.5

    def __post_init__(self):
        assert self.d_model % self.n_head == 0

    def subspace(self, kind: str) -> int:
        """d for a weight kind: d_frac * min(m, n), rounded to a multiple of 8."""
        _, dims = LSP_KINDS[kind]
        m, n = dims(self.d_model, self.d_ff)
        d = max(8, int(min(m, n) * self.d_frac))
        return d - d % 8

    def kind_dims(self, kind: str) -> tuple[int, int]:
        _, dims = LSP_KINDS[kind]
        return dims(self.d_model, self.d_ff)


def block_param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("ln1_g", (d,)), ("ln1_b", (d,)),
        ("w_qkv", (d, 3 * d)), ("b_qkv", (3 * d,)),
        ("w_o", (d, d)), ("b_o", (d,)),
        ("ln2_g", (d,)), ("ln2_b", (d,)),
        ("w_fc", (d, f)), ("b_fc", (f,)),
        ("w_pr", (f, d)), ("b_pr", (d,)),
    ]


def n_params(cfg: ModelConfig) -> int:
    per_block = sum(
        int(jnp.prod(jnp.array(s))) for _, s in block_param_specs(cfg)
    )
    return (
        cfg.vocab * cfg.d_model
        + cfg.seq * cfg.d_model
        + cfg.n_layer * per_block
        + 2 * cfg.d_model
    )


def _layer_norm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + _LN_EPS) * g + b


def _attention(q, k, v):
    if os.environ.get("LSP_ATTN", "ref") == "pallas":
        return flash_attention(q, k, v)
    return kref.attention_ref(q, k, v)


def _block_fn(h, params: Sequence[jax.Array], n_head: int):
    (ln1_g, ln1_b, w_qkv, b_qkv, w_o, b_o,
     ln2_g, ln2_b, w_fc, b_fc, w_pr, b_pr) = params
    bsz, t, d = h.shape
    dh = d // n_head

    a = _layer_norm(h, ln1_g, ln1_b)
    qkv = a @ w_qkv + b_qkv  # [B, T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    split = lambda x: x.reshape(bsz, t, n_head, dh).transpose(0, 2, 1, 3)
    att = _attention(split(q), split(k), split(v))  # [B, H, T, dh]
    att = att.transpose(0, 2, 1, 3).reshape(bsz, t, d)
    h = h + att @ w_o + b_o

    mlp_in = _layer_norm(h, ln2_g, ln2_b)
    h = h + jax.nn.gelu(mlp_in @ w_fc + b_fc) @ w_pr + b_pr
    return h


def _head_loss_fn(h, lnf_g, lnf_b, wte, targets):
    hn = _layer_norm(h, lnf_g, lnf_b)
    logits = hn @ wte.T  # tied embedding head, [B, T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean().reshape(1, 1)


# ---------------------------------------------------------------------------
# AOT entry points (every one returns a tuple; aot.py lowers them as-is).
# ---------------------------------------------------------------------------

def embed_fwd(tokens, wte, wpe):
    return (jnp.take(wte, tokens, axis=0) + wpe[None, :, :],)


def block_fwd(h, *params, n_head: int):
    return (_block_fn(h, params, n_head),)


def block_bwd(h_in, *params_and_dout, n_head: int):
    *params, d_out = params_and_dout
    fn = lambda h, ps: _block_fn(h, ps, n_head)
    _, vjp = jax.vjp(fn, h_in, tuple(params))
    d_in, d_params = vjp(d_out)
    return (d_in, *d_params)


def head_loss_fwd(h, lnf_g, lnf_b, wte, targets):
    return (_head_loss_fn(h, lnf_g, lnf_b, wte, targets),)


def head_loss_bwd(h, lnf_g, lnf_b, wte, targets):
    loss, grads = jax.value_and_grad(
        lambda *a: _head_loss_fn(*a, targets).reshape(()), argnums=(0, 1, 2, 3)
    )(h, lnf_g, lnf_b, wte)
    return (loss.reshape(1, 1), *grads)


def embed_bwd(tokens, d_h, *, vocab: int):
    d_model = d_h.shape[-1]
    d_wte = jnp.zeros((vocab, d_model), d_h.dtype).at[tokens].add(d_h)
    d_wpe = d_h.sum(axis=0)
    return (d_wte, d_wpe)


def train_step(tokens, targets, wte, wpe, *rest, cfg: ModelConfig):
    """Monolithic fwd+bwd: the native (no-offload) baseline + parity oracle.

    ``rest`` = n_layer * 12 block params followed by lnf_g, lnf_b.
    Returns (loss, d_wte, d_wpe, <block grads in order>, d_lnf_g, d_lnf_b).
    """
    npb = len(BLOCK_PARAM_NAMES)
    blocks = [rest[i * npb:(i + 1) * npb] for i in range(cfg.n_layer)]
    lnf_g, lnf_b = rest[cfg.n_layer * npb:]

    def loss_fn(wte, wpe, blocks, lnf_g, lnf_b):
        h = embed_fwd(tokens, wte, wpe)[0]
        for bp in blocks:
            h = _block_fn(h, bp, cfg.n_head)
        return _head_loss_fn(h, lnf_g, lnf_b, wte, targets).reshape(())

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3, 4))(
        wte, wpe, [tuple(b) for b in blocks], lnf_g, lnf_b
    )
    d_wte, d_wpe, d_blocks, d_lnf_g, d_lnf_b = grads
    flat = [g for blk in d_blocks for g in blk]
    return (loss.reshape(1, 1), d_wte, d_wpe, *flat, d_lnf_g, d_lnf_b)
