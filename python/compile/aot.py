"""AOT compiler: lower every L2/L1 entry point to HLO *text* + manifest.json.

Run once at build time (``make artifacts``); Python is never on the request
path.  The rust coordinator loads ``artifacts/manifest.json`` for shapes and
``artifacts/<entry>.hlo.txt`` for each executable.

Interchange is HLO text, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (what the ``xla``
rust crate links) rejects; the text parser re-assigns ids.  Lowering path:
jitted fn -> stablehlo -> ``mlir_module_to_xla_computation`` (return_tuple=
True, so rust unwraps a tuple uniformly) -> ``as_hlo_text``.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import proj_learn
from .kernels import formats
from .kernels.fused_adam import fused_adam
from .kernels.lsp_decompress import lsp_apply
from .kernels.lsp_project import lsp_compress
from .kernels import ref as kref

PRESETS: dict[str, M.ModelConfig] = {
    # Fast AOT + pytest + rust integration tests.
    "tiny": M.ModelConfig(vocab=64, d_model=32, n_head=2, d_ff=64,
                          n_layer=2, seq=16, batch=2, r=2),
    # Default e2e driver scale (~1M params).
    "small": M.ModelConfig(vocab=256, d_model=128, n_head=4, d_ff=512,
                           n_layer=4, seq=64, batch=8, r=4),
    # Ablation scale (~5M params).
    "mid": M.ModelConfig(vocab=256, d_model=256, n_head=8, d_ff=1024,
                         n_layer=6, seq=128, batch=8, r=4),
    # GPT2-small-like (~100M params with embeddings); CPU-PJRT heavy.
    "gpt2s": M.ModelConfig(vocab=50304, d_model=768, n_head=12, d_ff=3072,
                           n_layer=12, seq=256, batch=4, r=8),
}

_F32 = jnp.float32
_I32 = jnp.int32


def _spec(shape, dtype=_F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered, return_tuple: bool) -> str:
    """Single-output entries are lowered with return_tuple=False so their
    PJRT output buffer can feed the next executable directly (no host
    round-trip); multi-output entries get a tuple root."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _dt_name(dt) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(dt)]


class Builder:
    def __init__(self, cfg: M.ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.entries = []

    def add(self, name: str, fn, args: list[tuple[str, jax.ShapeDtypeStruct]]):
        specs = [s for _, s in args]
        # keep_unused: the manifest promises the rust side that HLO
        # parameters == declared args (e.g. block_bwd's b_pr grad does not
        # depend on b_pr's value, but the arg must survive DCE).
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        outs = jax.eval_shape(fn, *specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        tuple_out = len(outs) > 1
        text = to_hlo_text(lowered, return_tuple=tuple_out)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries.append({
            "name": name,
            "file": fname,
            "tuple_out": tuple_out,
            "args": [
                {"name": n, "dtype": _dt_name(s.dtype), "shape": list(s.shape)}
                for n, s in args
            ],
            "outs": [
                {"dtype": _dt_name(o.dtype), "shape": list(o.shape)}
                for o in outs
            ],
        })
        print(f"  lowered {name:24s} ({len(text)} chars)")


def build(cfg: M.ModelConfig, out_dir: str, *, monolith: bool,
          preset: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    b = Builder(cfg, out_dir)
    B, T, V, D = cfg.batch, cfg.seq, cfg.vocab, cfg.d_model
    bp = M.block_param_specs(cfg)
    block_args = [(n, _spec(s)) for n, s in bp]

    # ---- model layer entries -------------------------------------------
    b.add("embed_fwd", M.embed_fwd, [
        ("tokens", _spec((B, T), _I32)),
        ("wte", _spec((V, D))), ("wpe", _spec((T, D))),
    ])
    b.add("block_fwd", functools.partial(M.block_fwd, n_head=cfg.n_head),
          [("h", _spec((B, T, D)))] + block_args)
    b.add("block_bwd", functools.partial(M.block_bwd, n_head=cfg.n_head),
          [("h_in", _spec((B, T, D)))] + block_args
          + [("d_out", _spec((B, T, D)))])
    head_args = [("h", _spec((B, T, D))), ("lnf_g", _spec((D,))),
                 ("lnf_b", _spec((D,))), ("wte", _spec((V, D))),
                 ("targets", _spec((B, T), _I32))]
    b.add("head_loss_fwd", M.head_loss_fwd, head_args)
    b.add("head_loss_bwd", M.head_loss_bwd, head_args)
    b.add("embed_bwd", functools.partial(M.embed_bwd, vocab=V), [
        ("tokens", _spec((B, T), _I32)), ("d_h", _spec((B, T, D))),
    ])

    # ---- LSP entries, one set per weight kind --------------------------
    kinds_meta = {}
    for kind in M.LSP_KINDS:
        m, n = cfg.kind_dims(kind)
        d = cfg.subspace(kind)
        r = cfg.r
        lp = formats.gather_len(m, d, r)
        lq = formats.gather_len(n, d, r)
        kinds_meta[kind] = {
            "m": m, "n": n, "d": d, "r": r, "lp": lp, "lq": lq,
            "param_index": M.LSP_KINDS[kind][0],
        }
        row_p = [("p_idx", _spec((m, r), _I32)), ("p_val", _spec((m, r)))]
        row_q = [("q_idx", _spec((n, r), _I32)), ("q_val", _spec((n, r)))]

        b.add(f"compress_{kind}", lsp_compress, [
            ("g", _spec((m, n))),
            ("p_gidx", _spec((d, lp), _I32)), ("p_gval", _spec((d, lp))),
            ("q_gidx", _spec((d, lq), _I32)), ("q_gval", _spec((d, lq))),
        ])
        b.add(f"apply_{kind}", lsp_apply,
              [("w", _spec((m, n)))] + row_p + row_q
              + [("ds", _spec((d, d))), ("lr", _spec((1, 1)))])
        b.add(f"bias_{kind}",
              functools.partial(kref.bias_ref, d=d),
              [("g", _spec((m, n)))] + row_p + row_q)
        b.add(f"learn_{kind}",
              functools.partial(proj_learn.learn_step, d=d, beta=1e-4),
              [("g", _spec((m, n)))] + row_p + row_q + [
                  ("mp", _spec((m, r))), ("vp", _spec((m, r))),
                  ("mq", _spec((n, r))), ("vq", _spec((n, r))),
                  ("t", _spec((1, 1))), ("lr", _spec((1, 1))),
              ])
        b.add(f"adam_sub_{kind}", fused_adam, [
            ("g", _spec((d, d))), ("m", _spec((d, d))),
            ("v", _spec((d, d))), ("t", _spec((1, 1))),
        ])
        b.add(f"state_proj_{kind}",
              functools.partial(proj_learn.project_state, d=d),
              [("m_s", _spec((d, d))), ("v_s", _spec((d, d)))]
              + [("p_idx_old", _spec((m, r), _I32)), ("p_val_old", _spec((m, r))),
                 ("q_idx_old", _spec((n, r), _I32)), ("q_val_old", _spec((n, r))),
                 ("p_idx_new", _spec((m, r), _I32)), ("p_val_new", _spec((m, r))),
                 ("q_idx_new", _spec((n, r), _I32)), ("q_val_new", _spec((n, r)))])

    # ---- projector-learning d-sweep (Fig 9 bias study) ------------------
    # One extra learn entry per sweep point for the "fc" kind so the bias
    # study can compare *learned* projectors across subspace sizes.
    fc_m, fc_n = cfg.kind_dims("fc")
    fc_d = cfg.subspace("fc")
    for d_sweep in sorted({max(8, fc_d // 4), max(8, fc_d // 2), fc_d,
                           min(min(fc_m, fc_n), fc_d * 2)}):
        if d_sweep == fc_d:
            continue  # already covered by learn_fc
        b.add(f"learn_sweep_fc_d{d_sweep}",
              functools.partial(proj_learn.learn_step, d=d_sweep, beta=1e-4),
              [("g", _spec((fc_m, fc_n)))]
              + [("p_idx", _spec((fc_m, cfg.r), _I32)), ("p_val", _spec((fc_m, cfg.r)))]
              + [("q_idx", _spec((fc_n, cfg.r), _I32)), ("q_val", _spec((fc_n, cfg.r)))]
              + [
                  ("mp", _spec((fc_m, cfg.r))), ("vp", _spec((fc_m, cfg.r))),
                  ("mq", _spec((fc_n, cfg.r))), ("vq", _spec((fc_n, cfg.r))),
                  ("t", _spec((1, 1))), ("lr", _spec((1, 1))),
              ])

    # ---- dense apply (axpy) for every distinct parameter length --------
    # Used for non-LSP params always, and for LSP'd matrices by the
    # Zero-Offload baseline (full-gradient offload).
    lens = set()
    lens.add(V * D)
    lens.add(T * D)
    lens.add(2 * D)  # lnf_g + lnf_b packed
    for name, shape in bp:
        sz = 1
        for s in shape:
            sz *= s
        lens.add(sz)
    for ln in sorted(lens):
        b.add(f"axpy_{ln}",
              lambda w, delta, lr: (w - lr.reshape(()) * delta,),
              [("w", _spec((ln,))), ("delta", _spec((ln,))),
               ("lr", _spec((1, 1)))])

    # ---- monolithic train step (native baseline + parity oracle) -------
    if monolith:
        flat_params = [("wte", _spec((V, D))), ("wpe", _spec((T, D)))]
        for i in range(cfg.n_layer):
            flat_params += [(f"b{i}_{n}", _spec(s)) for n, s in bp]
        flat_params += [("lnf_g", _spec((D,))), ("lnf_b", _spec((D,)))]
        b.add("train_step", functools.partial(M.train_step, cfg=cfg),
              [("tokens", _spec((B, T), _I32)),
               ("targets", _spec((B, T), _I32))] + flat_params)

    manifest = {
        "preset": preset,
        "config": {
            "vocab": V, "d_model": D, "n_head": cfg.n_head,
            "d_ff": cfg.d_ff, "n_layer": cfg.n_layer, "seq": T, "batch": B,
            "r": cfg.r, "d_frac": cfg.d_frac,
            "n_params": int(M.n_params(cfg)),
        },
        "kinds": kinds_meta,
        "block_params": [{"name": n, "shape": list(s)} for n, s in bp],
        "axpy_lens": sorted(lens),
        "entries": b.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(b.entries)} entries to {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--no-monolith", action="store_true",
                    help="skip the monolithic train_step entry")
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    print(f"preset={args.preset} n_params={M.n_params(cfg):,}")
    build(cfg, args.out_dir, monolith=not args.no_monolith,
          preset=args.preset)


if __name__ == "__main__":
    main()
