"""Pure-jnp oracles for every L1 kernel.

These are the single source of truth for correctness: pytest asserts each
Pallas kernel (interpret mode) against the functions below, and the rust
integration tests check the loaded HLO artifacts against values produced by
the same math re-implemented in rust/src/sparse + rust/src/optim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "densify",
    "lsp_compress_ref",
    "lsp_apply_ref",
    "bias_ref",
    "adam_ref",
    "matmul_ref",
    "attention_ref",
]


def densify(idx: jax.Array, val: jax.Array, d: int) -> jax.Array:
    """ROW-layout (idx int32[m,r], val f32[m,r]) -> dense f32[m,d].

    Built from one-hots so it is differentiable w.r.t. ``val`` — the
    projector-learning step (Eq. 3) takes gradients through this.
    """
    one_hot = jax.nn.one_hot(idx, d, dtype=val.dtype)  # [m, r, d]
    return jnp.einsum("mr,mrd->md", val, one_hot)


def lsp_compress_ref(g, p_idx, p_val, q_idx, q_val, d: int):
    """S = P^T G Q  (Alg. 1 line 15), f32[d, d]."""
    p = densify(p_idx, p_val, d)  # [m, d]
    q = densify(q_idx, q_val, d)  # [n, d]
    return p.T @ g @ q


def lsp_apply_ref(w, p_idx, p_val, q_idx, q_val, ds, lr):
    """W' = W - lr * P dS Q^T  (Alg. 1 line 17)."""
    d = ds.shape[0]
    p = densify(p_idx, p_val, d)
    q = densify(q_idx, q_val, d)
    return w - lr * (p @ ds @ q.T)


def bias_ref(g, p_idx, p_val, q_idx, q_val, d: int):
    """Relative estimation bias ||P P^T G Q Q^T - G||_F / ||G||_F (Def. 2).

    Returns (rel_bias, abs_bias, g_norm) each shaped (1, 1) so the rust side
    never has to deal with rank-0 literals.
    """
    p = densify(p_idx, p_val, d)
    q = densify(q_idx, q_val, d)
    est = p @ (p.T @ g @ q) @ q.T
    abs_bias = jnp.linalg.norm(est - g)
    g_norm = jnp.linalg.norm(g)
    rel = abs_bias / jnp.maximum(g_norm, 1e-30)
    one = lambda x: x.reshape(1, 1)
    return one(rel), one(abs_bias), one(g_norm)


def adam_ref(g, m, v, t, beta1=0.9, beta2=0.999, eps=1e-8):
    """One Adam moment update; returns (delta, m', v').

    ``delta`` is the *unscaled* step m_hat / (sqrt(v_hat) + eps); the learning
    rate is applied GPU-side at decompress time (Alg. 1 line 17), matching
    Zero-Offload's split where the CPU computes delta and the GPU applies it.
    ``t`` is the 1-based step count, f32[1,1].
    """
    t = t.reshape(())
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * (g * g)
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    delta = mhat / (jnp.sqrt(vhat) + eps)
    return delta, m2, v2


def matmul_ref(a, b):
    return a @ b


def attention_ref(q, k, v):
    """Causal multi-head attention. q,k,v: f32[B, H, T, Dh]."""
    t = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    att = jnp.where(mask, att, jnp.finfo(att.dtype).min)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", att, v)
