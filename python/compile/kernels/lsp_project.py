"""Pallas compress kernel: S = P^T G Q with (d, r)-sparse P, Q.

The paper ships *dense* multiplies over sparsely-stored projectors and lists
"specialized sparse-matrix multiplication kernels" as future work (Limitation
section).  This kernel is that future work: the scatter `P^T G` is rewritten
as a *gather* over the padded-CSC layout (see formats.py), which on a real
TPU becomes, per (d-tile, n-tile), a small one-hot x G-tile matmul on the MXU
with G tiles double-buffered through VMEM.  Under interpret mode the gather
runs as plain numpy, which is what the CPU PJRT client executes.

Two stages, each its own pallas_call with a real grid:

  stage 1:  A = P^T G        grid over d-tiles of A's rows
  stage 2:  S = A Q          grid over d-tiles of S's columns

VMEM budget per grid step (stage 1): bd*L (idx+val) + m*n (G tile; on TPU the
n axis would be a second grid dim) + bd*n (out).  DESIGN.md carries the
footprint/MXU analysis for the paper's shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lsp_compress", "pt_g_kernel", "a_q_kernel"]


def _tile(n: int, target: int = 128) -> int:
    """Largest divisor of n that is <= target (grid tiles must divide)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def pt_g_kernel(gidx_ref, gval_ref, g_ref, out_ref, *, L: int):
    """A[j, :] = sum_l gval[j, l] * G[gidx[j, l], :] for a tile of j."""
    g = g_ref[...]  # [m, n]
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    for l in range(L):  # L is small & static: r * ceil(m/d)
        rows = gidx_ref[:, l]  # [bd]
        acc = acc + gval_ref[:, l][:, None] * jnp.take(g, rows, axis=0)
    out_ref[...] = acc


def a_q_kernel(gidx_ref, gval_ref, a_ref, out_ref, *, L: int):
    """S[:, c] = sum_l gval[c, l] * A[:, gidx[c, l]] for a tile of c."""
    a = a_ref[...]  # [d, n]
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    for l in range(L):
        cols = gidx_ref[:, l]  # [bc]
        acc = acc + gval_ref[:, l][None, :] * jnp.take(a, cols, axis=1)
    out_ref[...] = acc


def lsp_compress(g, p_gidx, p_gval, q_gidx, q_gval):
    """S = P^T G Q.

    Args:
      g:      f32[m, n] gradient.
      p_gidx: int32[d, Lp] gather layout of P   (row->subspace, see formats).
      p_gval: f32  [d, Lp]
      q_gidx: int32[d, Lq] gather layout of Q.
      q_gval: f32  [d, Lq]
    Returns:
      f32[d, d] compressed gradient.
    """
    m, n = g.shape
    d, lp = p_gidx.shape
    _, lq = q_gidx.shape

    bd = _tile(d)
    a = pl.pallas_call(
        functools.partial(pt_g_kernel, L=lp),
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((bd, lp), lambda i: (i, 0)),
            pl.BlockSpec((bd, lp), lambda i: (i, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=True,
    )(p_gidx, p_gval, g)

    bc = _tile(d)
    s = pl.pallas_call(
        functools.partial(a_q_kernel, L=lq),
        grid=(d // bc,),
        in_specs=[
            pl.BlockSpec((bc, lq), lambda i: (i, 0)),
            pl.BlockSpec((bc, lq), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d, bc), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(q_gidx, q_gval, a)
    return s
