"""Flash-style causal attention: Pallas forward, recompute backward.

The forward pass streams KV tiles through VMEM with an online-softmax
accumulator (running max + denominator), one (batch*head, q-tile) grid cell
per invocation — the standard flash decomposition, sized so a (bq, d_head)
query tile plus one (bk, d_head) KV tile fit in VMEM.

Pallas kernels have no automatic VJP, so the backward pass recomputes
attention with the jnp reference and differentiates that (jax.custom_vjp).
This *is* the paper's configuration: gradient checkpointing is enabled in
LSP-Offload's implementation, i.e. backward recomputes forward state anyway.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                     t: int, scale: float):
    iq = pl.program_id(1)
    q = q_ref[0, ...]  # [bq, dh]
    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)

    acc = jnp.zeros(q.shape, dtype=jnp.float32)
    m_i = jnp.full((bq,), _NEG_INF, dtype=jnp.float32)
    l_i = jnp.zeros((bq,), dtype=jnp.float32)

    # Causal: only KV tiles with start <= end of this q tile contribute.
    n_kv = (iq * bq + bq + bk - 1) // bk
    for jk in range(t // bk):  # static loop; masked out beyond n_kv
        if jk * bk >= 0:  # always true; keeps structure flat for interpret
            k = k_ref[0, ...][jk * bk:(jk + 1) * bk, :]  # [bk, dh]
            v = v_ref[0, ...][jk * bk:(jk + 1) * bk, :]
            k_pos = jk * bk + jax.lax.iota(jnp.int32, bk)
            s = (q @ k.T) * scale  # [bq, bk]
            causal = q_pos[:, None] >= k_pos[None, :]
            live = jk < n_kv
            s = jnp.where(causal & live, s, _NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m_i - m_new)
            l_i = l_i * alpha + p.sum(axis=1)
            acc = acc * alpha[:, None] + p @ v
            m_i = m_new
    o_ref[0, ...] = acc / jnp.maximum(l_i, 1e-30)[:, None]


def _tile(n: int, target: int) -> int:
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _flash_fwd(q, k, v):
    b, h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    bq = _tile(t, 64)
    bk = _tile(t, 64)
    qf = q.reshape(b * h, t, dh)
    kf = k.reshape(b * h, t, dh)
    vf = v.reshape(b * h, t, dh)
    out = pl.pallas_call(
        functools.partial(_attn_fwd_kernel, bq=bq, bk=bk, t=t, scale=scale),
        grid=(b * h, t // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, t, dh), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, t, dh)


@jax.custom_vjp
def flash_attention(q, k, v):
    """Causal MHA, q/k/v: f32[B, H, T, Dh] -> f32[B, H, T, Dh]."""
    return _flash_fwd(q, k, v)


def _fwd(q, k, v):
    return _flash_fwd(q, k, v), (q, k, v)


def _bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(_ref.attention_ref, q, k, v)  # recompute (checkpointing)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
