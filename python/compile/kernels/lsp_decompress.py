"""Pallas decompress/apply kernel: W' = W - lr * P dS Q^T.

Unlike compress, the ROW layout of the (d, r)-sparse projector is already
gather-friendly here:

  stage 1:  X = P dS          X[i, :] = sum_k p_val[i,k] * dS[p_idx[i,k], :]
                              grid over m-row tiles (r is tiny: 2..16)
  stage 2:  W' = W - lr X Q^T (W')[:, j] = W[:,j] - lr * sum_k q_val[j,k] * X[:, q_idx[j,k]]
                              grid over n-column tiles, subtract fused

On TPU, stage 1 is an r-term accumulation of dS row-tiles held in VMEM and
stage 2 streams W tiles HBM->VMEM->HBM exactly once — the apply step touches
each weight element once, matching the paper's claim that decompression adds
O(r) work per element.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lsp_apply"]


def _tile(n: int, target: int = 128) -> int:
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _p_ds_kernel(idx_ref, val_ref, ds_ref, out_ref, *, r: int):
    ds = ds_ref[...]  # [d, d]
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    for k in range(r):
        rows = idx_ref[:, k]  # [bm]
        acc = acc + val_ref[:, k][:, None] * jnp.take(ds, rows, axis=0)
    out_ref[...] = acc


def _x_qt_apply_kernel(idx_ref, val_ref, x_ref, w_ref, lr_ref, out_ref, *, r: int):
    x = x_ref[...]  # [m, d]
    lr = lr_ref[0, 0]
    upd = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    for k in range(r):
        cols = idx_ref[:, k]  # [bn]
        upd = upd + val_ref[:, k][None, :] * jnp.take(x, cols, axis=1)
    out_ref[...] = w_ref[...] - lr * upd


def lsp_apply(w, p_idx, p_val, q_idx, q_val, ds, lr):
    """W' = W - lr * P dS Q^T.

    Args:
      w:     f32[m, n] weight.
      p_idx: int32[m, r] ROW layout of P, p_val f32[m, r].
      q_idx: int32[n, r] ROW layout of Q, q_val f32[n, r].
      ds:    f32[d, d] subspace delta from the CPU update step.
      lr:    f32[1, 1] learning rate.
    """
    m, n = w.shape
    d = ds.shape[0]
    r = p_idx.shape[1]

    bm = _tile(m)
    x = pl.pallas_call(
        functools.partial(_p_ds_kernel, r=r),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(p_idx, p_val, ds)

    bn = _tile(n)
    rq = q_idx.shape[1]
    return pl.pallas_call(
        functools.partial(_x_qt_apply_kernel, r=rq),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, rq), lambda i: (i, 0)),
            pl.BlockSpec((bn, rq), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(q_idx, q_val, x, w, lr)
