"""L1 Pallas kernels for LSP-Offload.

Every kernel here runs with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode lowers the kernel to plain HLO
that any backend (including the rust ``xla`` crate's CPU client) can run.
Real-TPU performance is estimated analytically in DESIGN.md from the
BlockSpecs (VMEM footprint, MXU utilization).

Modules:
  formats        -- (d,r)-sparse projector layouts (row / padded-gather) + RNG
  ref            -- pure-jnp oracles every kernel is tested against
  lsp_project    -- compress  S = P^T G Q           (the paper's GPU-side hot spot)
  lsp_decompress -- apply     W' = W - lr * P dS Q^T
  fused_adam     -- the CPU-side parameter-update step (Zero-Offload's UPD)
  tiled_matmul   -- dense MXU-tiled matmul (paper-faithful dense compress path)
  attention      -- flash-style causal attention fwd with recompute bwd
"""
