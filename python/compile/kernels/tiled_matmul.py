"""Classic MXU-tiled dense matmul Pallas kernel.

This is the paper-faithful *dense* compress path: LSP-Offload as published
densifies the sparse projectors on the GPU and runs dense GEMMs (the sparse
kernel is its stated future work, implemented here in lsp_project.py).  The
tiled kernel also documents the TPU mapping we assume in the perf model:
(bm, bn) output tiles accumulated over bk-sized K panels, A/B panels
double-buffered through VMEM, bf16 inputs -> f32 accumulation on the MXU.

The K axis is the innermost grid dimension and the output BlockSpec does not
depend on it, so the same output tile is revisited across K steps and used
as the accumulator (the standard Pallas matmul pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tiled_matmul"]


def _mm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _tile(n: int, target: int) -> int:
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def tiled_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = A @ B over a (M/bm, N/bn, K/bk) grid. a: f32[M,K], b: f32[K,N]."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = _tile(m, bm), _tile(n, bn), _tile(k, bk)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
