"""(d, r)-sparse projector storage formats.

Definition 1 of the paper: a projector ``P in R^{m x d}`` is (d, r)-sparse if
every *row* has exactly ``r`` non-zero values.  We store it in two layouts:

ROW layout  (the canonical one, what the optimizer learns):
    idx : int32[m, r]   -- column index of each non-zero
    val : f32  [m, r]   -- its value

GATHER layout (padded CSC of P^T, what the compress kernel consumes):
    gidx : int32[d, L]  -- for subspace row j, the input rows that touch it
    gval : f32  [d, L]  -- the matching values (0 for padding slots)

``L`` must be static for AOT lowering, so non-zero *positions* are sampled
with a **balanced** construction: for each of the r "hash functions" we draw
a random permutation of the m rows and deal columns round-robin.  Every
subspace column then receives exactly ``ceil(m/d)`` entries per hash, hence
``L = r * ceil(m/d)`` exactly — no data-dependent padding.  This keeps the
JL-style unbiasedness of random sparse embeddings (Kane & Nelson 2014) while
making every shape static.

The rust coordinator re-implements both layouts bit-compatibly
(``rust/src/sparse/``); only the *shapes* must agree, the RNG need not.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "gather_len",
    "make_positions",
    "init_values",
    "row_to_gather",
    "densify",
]


def gather_len(m: int, d: int, r: int) -> int:
    """Static padded length of the gather layout: r * ceil(m / d)."""
    return r * ((m + d - 1) // d)


def make_positions(m: int, d: int, r: int, seed: int = 0) -> np.ndarray:
    """Balanced random non-zero positions, int32[m, r].

    For hash k: rows are randomly permuted and dealt round-robin over the d
    subspace columns, so column loads are exactly balanced.
    """
    if not (0 < r <= d):
        raise ValueError(f"need 0 < r <= d, got r={r} d={d}")
    rng = np.random.default_rng(seed)
    idx = np.empty((m, r), dtype=np.int32)
    for k in range(r):
        perm = rng.permutation(m)
        idx[perm, k] = (np.arange(m) % d).astype(np.int32)
    return idx


def init_values(m: int, r: int, seed: int = 0) -> np.ndarray:
    """JL init: values ~ N(0, 1/sqrt(r)), f32[m, r] (paper, Learned sparse
    projectors paragraph)."""
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((m, r)) / np.sqrt(r)).astype(np.float32)


def row_to_gather(
    idx: np.ndarray, val: np.ndarray, d: int
) -> tuple[np.ndarray, np.ndarray]:
    """Convert ROW layout -> GATHER layout.

    Returns (gidx int32[d, L], gval f32[d, L]).  Padding slots carry index 0
    and value 0 so the kernel's weighted gather is a no-op for them.
    """
    m, r = idx.shape
    L = gather_len(m, d, r)
    gidx = np.zeros((d, L), dtype=np.int32)
    gval = np.zeros((d, L), dtype=np.float32)
    fill = np.zeros(d, dtype=np.int64)
    # Stable row-major walk keeps the layout deterministic given (idx, val).
    for i in range(m):
        for k in range(r):
            j = int(idx[i, k])
            s = fill[j]
            if s >= L:  # only possible if positions are not balanced
                raise ValueError("column load exceeds static gather length")
            gidx[j, s] = i
            gval[j, s] = val[i, k]
            fill[j] = s + 1
    return gidx, gval


def densify(idx: np.ndarray, val: np.ndarray, d: int) -> np.ndarray:
    """ROW layout -> dense f32[m, d] (duplicate positions accumulate)."""
    m, r = idx.shape
    out = np.zeros((m, d), dtype=np.float32)
    rows = np.repeat(np.arange(m), r)
    np.add.at(out, (rows, idx.reshape(-1)), val.reshape(-1).astype(np.float32))
    return out
