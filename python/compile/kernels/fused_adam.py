"""Pallas fused-Adam kernel — the CPU-side UPD step of the offload schedule.

Zero-Offload's CPU update is a fused SIMD Adam loop (paper, Implementation);
LSP-Offload runs the same update but over the d x d subspace gradient.  This
kernel fuses moment update, bias correction, and step computation into one
pass so each of g/m/v is read once and delta/m'/v' written once — on TPU one
HBM->VMEM->HBM stream per array tiled over VPU lanes; on the CPU PJRT client
XLA fuses the lowered elementwise graph into a single loop, which is also
what the rust-native fused Adam (rust/src/optim) implements.

``delta`` is unscaled (m_hat / (sqrt(v_hat)+eps)); the learning rate is
applied at decompress time on the GPU side (Alg. 1 line 17).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_adam"]


def _adam_kernel(g_ref, m_ref, v_ref, t_ref, delta_ref, m_out_ref, v_out_ref,
                 *, beta1: float, beta2: float, eps: float):
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    t = t_ref[0, 0]
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * (g * g)
    mhat = m2 / (1.0 - jnp.power(beta1, t))
    vhat = v2 / (1.0 - jnp.power(beta2, t))
    delta_ref[...] = mhat / (jnp.sqrt(vhat) + eps)
    m_out_ref[...] = m2
    v_out_ref[...] = v2


def fused_adam(g, m, v, t, *, beta1=0.9, beta2=0.999, eps=1e-8):
    """One fused Adam step over a 2-D tensor.

    Args:
      g, m, v: f32[a, b] gradient and first/second moments.
      t:       f32[1, 1] 1-based step count (for bias correction).
    Returns:
      (delta, m', v') each f32[a, b].
    """
    a, b = g.shape
    ba = _row_tile(a)
    shp = jax.ShapeDtypeStruct((a, b), jnp.float32)
    blk = lambda: pl.BlockSpec((ba, b), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps),
        grid=(a // ba,),
        in_specs=[blk(), blk(), blk(), pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[blk(), blk(), blk()],
        out_shape=[shp, shp, shp],
        interpret=True,
    )(g, m, v, t)


def _row_tile(n: int, target: int = 256) -> int:
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t
